//! Figure 4: memory-management policy comparison under oversubscription.
//!
//! The paper's setup: 16 copies of the FFT function, each using 1.5 GB —
//! 24 GB of demand on a 16 GB V100 (150%). Each copy is sequentially
//! invoked 20 times. Policies: stock UVM, madvise, prefetch-only, and
//! the integrated prefetch+swap (default). Expected shape: stock ≈ +40%
//! exec time, madvise slightly worse, prefetch+swap ≈ ideal warm time.

use crate::memory::MemPolicy;
use crate::plane::PlaneConfig;
use crate::scheduler::policies::PolicyKind;
use crate::types::{secs, StartKind};
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::catalog::by_name;
use crate::workload::trace::{Trace, TraceEvent, Workload};

pub const COPIES: usize = 16;
pub const ROUNDS: usize = 20;

#[derive(Debug, Clone)]
pub struct Row {
    pub policy: &'static str,
    /// Mean warm execution time (kernel incl. fault stalls), seconds.
    pub exec_s: f64,
    /// Mean in-shim blocking time, seconds.
    pub in_shim_s: f64,
    /// Total = what the user experiences per invocation.
    pub total_s: f64,
}

fn workload() -> (Workload, Trace) {
    let class = by_name("fft").unwrap();
    let mut w = Workload::default();
    let mut t = Trace::default();
    let mut funcs = Vec::new();
    for c in 0..COPIES {
        funcs.push(w.register(class, c, 30.0));
    }
    // Round-robin sequential invocations: copy 0..15, repeat — each
    // round touches all 16 working sets, forcing the 150% churn.
    let spacing = 2.0; // > warm exec (0.897 s): sequential, D=1 drains
    for round in 0..ROUNDS {
        for (c, f) in funcs.iter().enumerate() {
            t.events.push(TraceEvent {
                at: secs((round * COPIES + c) as f64 * spacing),
                func: *f,
            });
        }
    }
    t.sort();
    (w, t)
}

pub fn measure(policy: MemPolicy) -> Row {
    let (w, t) = workload();
    let cfg = PlaneConfig {
        policy: PolicyKind::Mqfq,
        mem_policy: policy,
        d: 1,
        pool_size: COPIES + 1,
        ..Default::default()
    };
    let r = crate::sim::replay(w, &t, cfg);
    let warm: Vec<&crate::metrics::InvRecord> = r
        .recorder()
        .records
        .iter()
        .filter(|rec| rec.start_kind != StartKind::Cold)
        .collect();
    assert!(!warm.is_empty());
    let exec = warm.iter().map(|r| r.exec_s()).sum::<f64>() / warm.len() as f64;
    let shim = warm.iter().map(|r| r.in_shim_s()).sum::<f64>() / warm.len() as f64;
    Row {
        policy: policy.name(),
        exec_s: exec,
        in_shim_s: shim,
        total_s: exec + shim,
    }
}

pub fn rows() -> Vec<Row> {
    [
        MemPolicy::StockUvm,
        MemPolicy::Madvise,
        MemPolicy::PrefetchOnly,
        MemPolicy::PrefetchSwap,
    ]
    .into_iter()
    .map(measure)
    .collect()
}

pub fn main() {
    println!(
        "== Figure 4: memory policies, {COPIES}×1.5GB FFT on 16GB V100 \
         (150% oversubscription), {ROUNDS} sequential rounds =="
    );
    let rows = rows();
    let ideal = by_name("fft").unwrap().gpu_warm_s;
    let mut t = Table::new(&["policy", "exec(s)", "in-shim(s)", "total(s)", "vs-ideal%"]);
    let mut csv = CsvWriter::create(
        "results/fig4.csv",
        &["policy", "exec_s", "in_shim_s", "total_s"],
    )
    .unwrap();
    for r in &rows {
        t.row(&[
            r.policy.to_string(),
            format!("{:.3}", r.exec_s),
            format!("{:.3}", r.in_shim_s),
            format!("{:.3}", r.total_s),
            format!("{:+.1}", (r.total_s / ideal - 1.0) * 100.0),
        ]);
        csv.rowv(&[
            r.policy.to_string(),
            format!("{:.4}", r.exec_s),
            format!("{:.4}", r.in_shim_s),
            format!("{:.4}", r.total_s),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(ideal non-UVM warm exec: {ideal:.3}s; paper: stock +40%, prefetch+swap ≈ ideal)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_ordering_matches_fig4() {
        let stock = measure(MemPolicy::StockUvm);
        let madv = measure(MemPolicy::Madvise);
        let swap = measure(MemPolicy::PrefetchSwap);
        // Madvise slightly worse than stock; prefetch+swap best.
        assert!(madv.total_s > stock.total_s, "{madv:?} vs {stock:?}");
        assert!(swap.total_s < stock.total_s, "{swap:?} vs {stock:?}");
        // Stock UVM meaningfully above ideal; prefetch+swap near ideal.
        let ideal = by_name("fft").unwrap().gpu_warm_s;
        assert!(
            stock.total_s / ideal > 1.25,
            "stock {} vs ideal {ideal}",
            stock.total_s
        );
        // Near-ideal: the residual is the exposed PCIe transfer on
        // sequential (no queue wait to hide it) invocations.
        assert!(
            swap.total_s / ideal < 1.25,
            "prefetch+swap {} vs ideal {ideal}",
            swap.total_s
        );
    }
}
