//! Table 1: warm/cold × GPU/CPU latency per catalog function.
//!
//! GPU columns are *measured* through the full stack: a fresh control
//! plane per function, one cold invocation then one warm invocation.
//! CPU columns come from the catalog's CPU cost model (one core, as in
//! the paper's allocation) plus the CPU cold-phase model.

use crate::container::ColdPhases;
use crate::plane::PlaneConfig;
use crate::scheduler::policies::PolicyKind;
use crate::types::{secs, StartKind};
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::catalog::{table1, FuncClass};
use crate::workload::trace::{Trace, TraceEvent, Workload};

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: &'static str,
    pub gpu_warm_s: f64,
    pub cpu_warm_s: f64,
    pub gpu_cold_s: f64,
    pub cpu_cold_s: f64,
}

/// Measure one function's GPU cold + warm latency through the plane.
fn measure_gpu(class: &'static FuncClass) -> (f64, f64) {
    let mut w = Workload::default();
    let f = w.register(class, 0, 10.0);
    let mut t = Trace::default();
    // First invocation cold; second long after (still within TTL-free
    // warm pool) is GPU-warm.
    t.events.push(TraceEvent { at: 0, func: f });
    t.events.push(TraceEvent {
        at: secs(class.gpu_cold_s() + 60.0),
        func: f,
    });
    let cfg = PlaneConfig {
        policy: PolicyKind::Mqfq,
        d: 1,
        ..Default::default()
    };
    let r = crate::sim::replay(w, &t, cfg);
    let recs = &r.recorder().records;
    assert_eq!(recs.len(), 2);
    let cold = recs
        .iter()
        .find(|r| r.start_kind == StartKind::Cold)
        .expect("no cold start");
    let warm = recs
        .iter()
        .find(|r| r.start_kind != StartKind::Cold)
        .expect("no warm start");
    (warm.latency_s(), cold.latency_s())
}

/// Compute all rows.
pub fn rows() -> Vec<Row> {
    table1()
        .into_iter()
        .map(|class| {
            let (gpu_warm, gpu_cold) = measure_gpu(class);
            Row {
                name: class.name,
                gpu_warm_s: gpu_warm,
                cpu_warm_s: class.cpu_warm_s,
                gpu_cold_s: gpu_cold,
                cpu_cold_s: class.cpu_warm_s + ColdPhases::for_class_cpu(class).total_s(),
            }
        })
        .collect()
}

pub fn main() {
    println!("== Table 1: GPU/CPU warm & cold invocation latencies (s) ==");
    let rows = rows();
    let mut t = Table::new(&["Function", "GPU [W]", "CPU [W]", "GPU [C]", "CPU [C]"]);
    let mut csv = CsvWriter::create(
        "results/table1.csv",
        &["function", "gpu_warm_s", "cpu_warm_s", "gpu_cold_s", "cpu_cold_s"],
    )
    .expect("results dir");
    for r in &rows {
        t.row(&[
            r.name.to_string(),
            format!("{:.3}", r.gpu_warm_s),
            format!("{:.3}", r.cpu_warm_s),
            format!("{:.3}", r.gpu_cold_s),
            format!("{:.3}", r.cpu_cold_s),
        ]);
        csv.rowv(&[
            r.name.to_string(),
            format!("{:.3}", r.gpu_warm_s),
            format!("{:.3}", r.cpu_warm_s),
            format!("{:.3}", r.gpu_cold_s),
            format!("{:.3}", r.cpu_cold_s),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(paper Table 1 reference: imagenet 2.253/5.477/11.286/10.103 …)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_measurements_track_table1() {
        let rows = rows();
        assert_eq!(rows.len(), 8);
        for r in &rows {
            let class = crate::workload::catalog::by_name(r.name).unwrap();
            // Warm latency = warm exec + shim overhead + marshal; within 40%.
            assert!(
                (r.gpu_warm_s - class.gpu_warm_s) / class.gpu_warm_s < 0.4,
                "{}: warm {} vs {}",
                r.name,
                r.gpu_warm_s,
                class.gpu_warm_s
            );
            // Cold latency within 15% of the Table-1 value.
            let err = (r.gpu_cold_s - class.gpu_cold_s()).abs() / class.gpu_cold_s();
            assert!(err < 0.15, "{}: cold {} vs {}", r.name, r.gpu_cold_s, class.gpu_cold_s());
            // The paper's premise rows: cold ≥ warm.
            assert!(r.gpu_cold_s >= r.gpu_warm_s);
        }
    }
}
