//! Figure 7: hardware multiplexing (MPS, MIG) and multi-GPU scaling.
//!
//! * 7a — A30: MQFQ alone vs MQFQ+MIG vs pure MPS (no queueing policy,
//!   high D) vs MQFQ+MPS, normalized to MQFQ alone, across Azure traces.
//! * 7b — per-function slowdown on a half-GPU MIG slice.
//! * 7c — 1 vs 2 V100s across D on a high-load trace.

use crate::gpu::{uniform_fleet, Device, DeviceSpec, MultiplexMode, A30, V100};
use crate::plane::PlaneConfig;
use crate::scheduler::policies::PolicyKind;
use crate::types::GpuId;
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::azure::{self, AzureConfig};
use crate::workload::catalog::CATALOG;

use super::{run, RunSummary};

/// One 7a configuration on one Azure trace.
fn run_7a(trace_id: usize, label: &str, cfg: PlaneConfig) -> RunSummary {
    let (w, t) = azure::generate(&AzureConfig {
        trace_id,
        duration_s: 600.0,
        load_scale: 1.0,
    });
    run(&format!("trace{trace_id} {label}"), w, &t, cfg).0
}

pub fn fig7a_rows(trace_id: usize) -> Vec<(String, f64)> {
    let base = PlaneConfig {
        devices: uniform_fleet(1, A30, MultiplexMode::Plain),
        policy: PolicyKind::Mqfq,
        d: 2,
        ..Default::default()
    };
    let configs: Vec<(&str, PlaneConfig)> = vec![
        ("mqfq", base.clone()),
        (
            "mqfq+mig",
            PlaneConfig {
                devices: uniform_fleet(1, A30, MultiplexMode::Mig(2)),
                ..base.clone()
            },
        ),
        (
            // Pure MPS: hardware multiplexes kernel launches, control
            // plane just shovels work in arrival order at high D.
            "mps-only",
            PlaneConfig {
                devices: uniform_fleet(1, A30, MultiplexMode::Mps),
                policy: PolicyKind::Fcfs,
                d: 8,
                ..base.clone()
            },
        ),
        (
            "mqfq+mps",
            PlaneConfig {
                devices: uniform_fleet(1, A30, MultiplexMode::Mps),
                ..base.clone()
            },
        ),
    ];
    let runs: Vec<(String, f64)> = configs
        .into_iter()
        .map(|(label, cfg)| {
            let s = run_7a(trace_id, label, cfg);
            (label.to_string(), s.wavg_latency_s)
        })
        .collect();
    let baseline = runs[0].1;
    runs.into_iter()
        .map(|(l, v)| (l, v / baseline))
        .collect()
}

pub fn fig7a() {
    println!("== Figure 7a: MPS/MIG latency normalized to MQFQ (A30) ==");
    let mut t = Table::new(&["trace", "mqfq", "mqfq+mig", "mps-only", "mqfq+mps"]);
    let mut csv = CsvWriter::create(
        "results/fig7a.csv",
        &["trace", "mqfq", "mqfq_mig", "mps_only", "mqfq_mps"],
    )
    .unwrap();
    for trace_id in [2, 4, 6] {
        let rows = fig7a_rows(trace_id);
        t.row(&[
            format!("{trace_id}"),
            format!("{:.2}", rows[0].1),
            format!("{:.2}", rows[1].1),
            format!("{:.2}", rows[2].1),
            format!("{:.2}", rows[3].1),
        ]);
        csv.rowv(&[
            trace_id.to_string(),
            format!("{:.3}", rows[0].1),
            format!("{:.3}", rows[1].1),
            format!("{:.3}", rows[2].1),
            format!("{:.3}", rows[3].1),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(paper: MIG hurts; pure MPS +3–240%; MQFQ+MPS best — up to −80%)");
}

pub fn fig7b_rows() -> Vec<(&'static str, f64)> {
    let full = Device::new(GpuId(0), DeviceSpec::new(A30, MultiplexMode::Plain));
    let slice = Device::new(GpuId(1), DeviceSpec::new(A30, MultiplexMode::Mig(2)));
    CATALOG
        .iter()
        .map(|c| {
            let ratio =
                slice.exec_time(c, true) as f64 / full.exec_time(c, true) as f64;
            (c.name, ratio)
        })
        .collect()
}

pub fn fig7b() {
    println!("== Figure 7b: execution slowdown on a half-GPU MIG slice ==");
    let mut t = Table::new(&["function", "slowdown×"]);
    let mut csv = CsvWriter::create("results/fig7b.csv", &["function", "slowdown"]).unwrap();
    for (name, ratio) in fig7b_rows() {
        t.row(&[name.to_string(), format!("{ratio:.2}")]);
        csv.rowv(&[name.to_string(), format!("{ratio:.3}")]).unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(paper: RNN/SRAD/FFT see the largest slowdowns)");
}

pub fn fig7c_rows() -> Vec<RunSummary> {
    // High-load trace: scale trace 6 (80% util on one GPU) up.
    let mut rows = Vec::new();
    for n_gpus in [1usize, 2] {
        for d in [1usize, 2, 3] {
            let (w, t) = azure::generate(&AzureConfig {
                trace_id: 6,
                duration_s: 600.0,
                load_scale: 1.4,
            });
            let cfg = PlaneConfig {
                devices: uniform_fleet(n_gpus, V100, MultiplexMode::Plain),
                d,
                policy: PolicyKind::Mqfq,
                ..Default::default()
            };
            let (s, _) = run(&format!("{n_gpus}xV100 D={d}"), w, &t, cfg);
            rows.push(s);
        }
    }
    rows
}

pub fn fig7c() {
    println!("== Figure 7c: multi-GPU scaling (high-load trace) ==");
    let rows = fig7c_rows();
    print!("{}", super::summary_table(&rows).render());
    super::write_summary_csv("fig7c", &rows).unwrap();
    println!("(paper: 2 GPUs give 2.3× at D=1, ~4× at higher D)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mig_slowdown_ordering() {
        let rows = fig7b_rows();
        let get = |n: &str| rows.iter().find(|(name, _)| *name == n).unwrap().1;
        assert!(get("rnn") > 2.0);
        assert!(get("srad") > 2.0);
        assert!(get("fft") > 1.5);
        assert!(get("isoneural") < 1.3);
    }

    #[test]
    fn mqfq_mps_beats_mps_only() {
        let rows = fig7a_rows(4);
        let get = |n: &str| rows.iter().find(|(name, _)| name == n).unwrap().1;
        assert!(
            get("mqfq+mps") < get("mps-only"),
            "mqfq+mps {:.2} vs mps-only {:.2}",
            get("mqfq+mps"),
            get("mps-only")
        );
        // MQFQ+MPS should also beat plain MQFQ (lower interference).
        assert!(get("mqfq+mps") <= 1.0 + 1e-9);
    }

    #[test]
    fn two_gpus_scale_latency_down() {
        let rows = fig7c_rows();
        let one_d2 = rows.iter().find(|r| r.label == "1xV100 D=2").unwrap();
        let two_d2 = rows.iter().find(|r| r.label == "2xV100 D=2").unwrap();
        assert!(
            two_d2.wavg_latency_s < one_d2.wavg_latency_s / 1.5,
            "2 GPUs {:.2}s vs 1 GPU {:.2}s",
            two_d2.wavg_latency_s,
            one_d2.wavg_latency_s
        );
    }
}
