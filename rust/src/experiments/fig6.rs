//! Figure 6: queueing-policy comparison on the medium-intensity Azure
//! workload (trace 4, 19 functions, ~70% utilization).
//!
//! * 6a — average latency per policy × device parallelism D ∈ {1,2,3},
//!   plus the FCFS-Naïve (no container pool) baseline.
//! * 6b — per-function mean latency + variance per policy (D=2).
//! * 6c — device utilization timeline for the same run.

use crate::plane::PlaneConfig;
use crate::scheduler::policies::{PolicyKind, FIG6_POLICIES};
use crate::types::to_secs;
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::azure::{self, AzureConfig};
use crate::workload::{Trace, Workload};

use super::{run, summary_table, write_summary_csv, RunSummary};

pub fn medium_workload() -> (Workload, Trace) {
    azure::generate(&AzureConfig {
        trace_id: 4,
        duration_s: 600.0,
        load_scale: 1.0,
    })
}

pub fn run_policy(policy: PolicyKind, d: usize, keep_warm: bool) -> RunSummary {
    let (w, t) = medium_workload();
    let cfg = PlaneConfig {
        policy,
        d,
        keep_warm,
        ..Default::default()
    };
    let label = if keep_warm {
        format!("{} D={d}", policy.name())
    } else {
        format!("{}-naive D={d}", policy.name())
    };
    run(&label, w, &t, cfg).0
}

pub fn fig6a() {
    println!("== Figure 6a: avg latency per policy × D (Azure trace 4) ==");
    let mut rows = Vec::new();
    // The paper's un-optimized baseline: nvidia-docker FCFS, no pool.
    rows.push(run_policy(PolicyKind::Fcfs, 1, false));
    for d in [1, 2, 3] {
        for policy in FIG6_POLICIES {
            rows.push(run_policy(policy, d, true));
        }
    }
    print!("{}", summary_table(&rows).render());
    write_summary_csv("fig6a", &rows).unwrap();
    println!(
        "(paper: naïve ≈3000s; MQFQ 11.8s vs FCFS 51.8s at D=1; \
         MQFQ-D2 ≈8.9s; Paella 8–20× worse; D=3 degrades everyone)"
    );
}

pub fn fig6b() {
    println!("== Figure 6b: per-function latency mean ± stddev (D=2) ==");
    let mut csv = CsvWriter::create(
        "results/fig6b.csv",
        &["policy", "function", "invocations", "mean_latency_s", "stddev_s"],
    )
    .unwrap();
    let mut t = Table::new(&["policy", "inter-fn variance", "mean of per-fn stddev"]);
    for policy in FIG6_POLICIES {
        let (w, tr) = medium_workload();
        let cfg = PlaneConfig {
            policy,
            d: 2,
            ..Default::default()
        };
        let r = crate::sim::replay(w.clone(), &tr, cfg);
        let aggs = r.recorder().per_function();
        for a in &aggs {
            csv.rowv(&[
                policy.name().to_string(),
                w.func(a.func).name.clone(),
                a.invocations.to_string(),
                format!("{:.3}", a.mean_latency_s),
                format!("{:.3}", a.var_latency.sqrt()),
            ])
            .unwrap();
        }
        let mean_sd = aggs.iter().map(|a| a.var_latency.sqrt()).sum::<f64>()
            / aggs.len().max(1) as f64;
        t.row(&[
            policy.name().to_string(),
            format!("{:.1}", r.recorder().inter_function_variance()),
            format!("{:.2}", mean_sd),
        ]);
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(paper: FCFS variance 752; MQFQ one-third of that; 3–4× lower error bars)");
}

pub fn fig6c() {
    println!("== Figure 6c: device utilization timeline (MQFQ, D=2) ==");
    let (w, tr) = medium_workload();
    let cfg = PlaneConfig {
        policy: PolicyKind::Mqfq,
        d: 2,
        ..Default::default()
    };
    let r = crate::sim::replay(w, &tr, cfg);
    let mut csv = CsvWriter::create("results/fig6c.csv", &["t_s", "util", "d"]).unwrap();
    for ((at, util), (_, d)) in r
        .recorder()
        .util_timeline
        .iter()
        .zip(r.recorder().d_timeline.iter())
    {
        csv.rowv(&[
            format!("{:.1}", to_secs(*at)),
            format!("{util:.3}"),
            d.to_string(),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    println!(
        "samples={} mean-util={:.1}% (paper: ~70% average on this trace)",
        r.recorder().util_timeline.len(),
        r.mean_util * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mqfq_beats_fcfs_on_medium_trace() {
        let fcfs = run_policy(PolicyKind::Fcfs, 1, true);
        let mqfq = run_policy(PolicyKind::Mqfq, 1, true);
        assert!(
            mqfq.wavg_latency_s < fcfs.wavg_latency_s / 1.5,
            "MQFQ {:.2}s vs FCFS {:.2}s — expected ≥1.5× win",
            mqfq.wavg_latency_s,
            fcfs.wavg_latency_s
        );
    }

    #[test]
    fn naive_is_catastrophically_slow() {
        let naive = run_policy(PolicyKind::Fcfs, 2, false);
        let pooled = run_policy(PolicyKind::Fcfs, 2, true);
        assert!(
            naive.wavg_latency_s > 5.0 * pooled.wavg_latency_s,
            "naive {:.1}s vs pooled {:.1}s",
            naive.wavg_latency_s,
            pooled.wavg_latency_s
        );
        assert!(naive.cold_ratio > 0.95);
    }

    #[test]
    fn d2_beats_d1_for_mqfq() {
        let d1 = run_policy(PolicyKind::Mqfq, 1, true);
        let d2 = run_policy(PolicyKind::Mqfq, 2, true);
        assert!(
            d2.wavg_latency_s < d1.wavg_latency_s,
            "D=2 {:.2}s should beat D=1 {:.2}s",
            d2.wavg_latency_s,
            d1.wavg_latency_s
        );
    }

    #[test]
    fn mqfq_has_lower_variance_than_fcfs() {
        let fcfs = run_policy(PolicyKind::Fcfs, 2, true);
        let mqfq = run_policy(PolicyKind::Mqfq, 2, true);
        assert!(
            mqfq.inter_fn_variance < fcfs.inter_fn_variance,
            "MQFQ var {:.1} vs FCFS {:.1}",
            mqfq.inter_fn_variance,
            fcfs.inter_fn_variance
        );
    }
}
