//! Figure 5: fairness and latency of MQFQ-Sticky vs FCFS.
//!
//! * 5a — GPU service over 30 s windows for four cupy copies (two
//!   popular, two added at the 5-minute mark); FCFS lets the popular
//!   pair dominate, MQFQ equalizes.
//! * 5b — max service gap among backlogged functions vs the Eq-1 bound.
//! * 5c — weighted-average latency vs offered load, all-functions and
//!   large-functions-only workloads.

use crate::metrics::{fairness_bound_eq1, service_windows};
use crate::plane::PlaneConfig;
use crate::scheduler::policies::PolicyKind;
use crate::types::{secs, to_secs, SEC};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::workload::catalog::by_name;
use crate::workload::trace::{Trace, TraceEvent, Workload};
use crate::workload::zipf::{self, ZipfConfig};

use super::{run, summary_table, write_summary_csv};

// ---------------------------------------------------------------- 5a ---

/// Build the 5a workload: 4 cupy copies; "High" pair (short IAT) active
/// from t=0, "Low" pair joins at t=300 s. 20-minute horizon.
pub fn fig5a_workload(base_iat_s: f64) -> (Workload, Trace) {
    let class = by_name("cupy").unwrap();
    let mut w = Workload::default();
    let mut rng = Rng::new(55);
    let mut t = Trace::default();
    let horizon = 1200.0;
    for copy in 0..4 {
        let (iat, start) = if copy < 2 {
            (base_iat_s, 0.0) // High: active immediately
        } else {
            (2.0 * base_iat_s, 300.0) // Low: join at the 5-minute mark
        };
        let f = w.register(class, copy, iat);
        let mut at = start + rng.exp(iat);
        while at < horizon {
            t.events.push(TraceEvent {
                at: secs(at),
                func: f,
            });
            at += rng.exp(iat);
        }
    }
    t.sort();
    (w, t)
}

/// Per-window service for each of the four functions under `policy`.
pub fn fig5a_series(policy: PolicyKind) -> Vec<(f64, Vec<f64>)> {
    // base IAT 1.5 s over cupy (1.2 s warm): aggregate demand ≈ 2.4
    // GPU-seconds/second once all four flows are active — the flows stay
    // backlogged, so *scheduling* (not demand) determines service, as in
    // the paper's experiment.
    let (w, t) = fig5a_workload(1.5);
    let cfg = PlaneConfig {
        policy,
        d: 2,
        ..Default::default()
    };
    let r = crate::sim::replay(w, &t, cfg);
    let horizon = r.makespan.max(secs(1200.0));
    let windows = service_windows(&r.recorder().records, 4, 30 * SEC, horizon);
    windows
        .iter()
        .map(|win| (to_secs(win.start), win.service_s.clone()))
        .collect()
}

pub fn fig5a() {
    println!("== Figure 5a: per-function GPU service over time (30 s windows) ==");
    let mut csv = CsvWriter::create(
        "results/fig5a.csv",
        &["policy", "window_start_s", "high0_s", "high1_s", "low0_s", "low1_s"],
    )
    .unwrap();
    for policy in [PolicyKind::Fcfs, PolicyKind::Mqfq] {
        let series = fig5a_series(policy);
        for (start, svc) in &series {
            csv.rowv(&[
                policy.name().to_string(),
                format!("{start:.0}"),
                format!("{:.2}", svc[0]),
                format!("{:.2}", svc[1]),
                format!("{:.2}", svc[2]),
                format!("{:.2}", svc[3]),
            ])
            .unwrap();
        }
        // Summarize the steady-state (after both pairs active).
        let steady: Vec<&(f64, Vec<f64>)> =
            series.iter().filter(|(s, _)| *s >= 400.0 && *s < 1100.0).collect();
        let mean_of = |i: usize| {
            steady.iter().map(|(_, v)| v[i]).sum::<f64>() / steady.len().max(1) as f64
        };
        println!(
            "{:>6}: steady-state service/window  high={:.1}s,{:.1}s  low={:.1}s,{:.1}s",
            policy.name(),
            mean_of(0),
            mean_of(1),
            mean_of(2),
            mean_of(3)
        );
    }
    csv.flush().unwrap();
    println!("(paper: FCFS lets the popular pair dominate; MQFQ equalizes all four)");
}

// ---------------------------------------------------------------- 5b ---

pub struct Fig5bResult {
    pub windows: Vec<(f64, f64)>, // (window start s, max gap s)
    pub mean_gap_s: f64,
    pub bound_s: f64,
}

pub fn fig5b_result() -> Fig5bResult {
    let (w, t) = zipf::generate(&ZipfConfig {
        n_funcs: 24,
        total_rate: 2.0,
        duration_s: 1200.0,
        seed: 5,
        ..Default::default()
    });
    let cfg = PlaneConfig {
        policy: PolicyKind::Mqfq,
        d: 2,
        ..Default::default()
    };
    let taus: Vec<f64> = w.funcs.iter().map(|f| f.class.gpu_warm_s).collect();
    let tau_max = taus.iter().cloned().fold(f64::MIN, f64::max);
    let tau_min = taus.iter().cloned().fold(f64::MAX, f64::min);
    let n = w.len();
    let r = crate::sim::replay(w, &t, cfg);
    let windows = service_windows(&r.recorder().records, n, 30 * SEC, r.makespan);
    let gaps: Vec<(f64, f64)> = windows
        .iter()
        .map(|win| (to_secs(win.start), win.max_gap_s()))
        .collect();
    let mean = gaps.iter().map(|(_, g)| g).sum::<f64>() / gaps.len().max(1) as f64;
    Fig5bResult {
        windows: gaps,
        mean_gap_s: mean,
        bound_s: fairness_bound_eq1(2, 10.0, tau_max, tau_min),
    }
}

pub fn fig5b() {
    println!("== Figure 5b: max service gap vs Eq-1 theoretical bound ==");
    let r = fig5b_result();
    let mut csv =
        CsvWriter::create("results/fig5b.csv", &["window_start_s", "max_gap_s", "bound_s"])
            .unwrap();
    for (s, g) in &r.windows {
        csv.rowv(&[format!("{s:.0}"), format!("{g:.3}"), format!("{:.3}", r.bound_s)])
            .unwrap();
    }
    csv.flush().unwrap();
    let max = r.windows.iter().map(|(_, g)| *g).fold(f64::MIN, f64::max);
    println!(
        "mean gap {:.1}s, max gap {:.1}s, Eq-1 bound {:.1}s  (paper: avg <50 vs bound 411)",
        r.mean_gap_s, max, r.bound_s
    );
}

// ---------------------------------------------------------------- 5c ---

pub fn fig5c() {
    println!("== Figure 5c: weighted-avg latency vs offered load ==");
    let mut rows = Vec::new();
    fn large_only(c: &crate::workload::FuncClass) -> bool {
        c.gpu_warm_s > 1.0
    }
    for &(label, filter) in &[
        ("all-24", None::<fn(&crate::workload::FuncClass) -> bool>),
        ("large-only", Some(large_only as fn(&crate::workload::FuncClass) -> bool)),
    ] {
        for rate in [0.5, 1.0, 2.0, 3.0, 4.0] {
            for policy in [PolicyKind::Fcfs, PolicyKind::Mqfq] {
                let (w, t) = zipf::generate(&ZipfConfig {
                    n_funcs: 24,
                    total_rate: rate,
                    duration_s: 600.0,
                    seed: 9,
                    class_filter: filter,
                    ..Default::default()
                });
                let cfg = PlaneConfig {
                    policy,
                    d: 2,
                    ..Default::default()
                };
                let (s, _) = run(
                    &format!("{label} rate={rate} {}", policy.name()),
                    w,
                    &t,
                    cfg,
                );
                rows.push(s);
            }
        }
    }
    print!("{}", summary_table(&rows).render());
    write_summary_csv("fig5c", &rows).unwrap();
    println!("(paper: MQFQ ≥2× lower latency at high load; ~15% on large-only)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5a_mqfq_equalizes_service() {
        let series = fig5a_series(PolicyKind::Mqfq);
        let steady: Vec<&(f64, Vec<f64>)> = series
            .iter()
            .filter(|(s, v)| *s >= 400.0 && *s < 1100.0 && v.iter().sum::<f64>() > 1.0)
            .collect();
        assert!(steady.len() > 5);
        let mean = |i: usize| {
            steady.iter().map(|(_, v)| v[i]).sum::<f64>() / steady.len() as f64
        };
        // All four flows backlogged → near-equal service; allow 45%
        // spread (windows are small relative to service quanta).
        let means = [mean(0), mean(1), mean(2), mean(3)];
        let avg = means.iter().sum::<f64>() / 4.0;
        for m in means {
            assert!(
                (m - avg).abs() / avg < 0.45,
                "MQFQ service uneven: {means:?}"
            );
        }
    }

    #[test]
    fn fig5a_fcfs_favors_popular() {
        let series = fig5a_series(PolicyKind::Fcfs);
        let steady: Vec<&(f64, Vec<f64>)> = series
            .iter()
            .filter(|(s, v)| *s >= 400.0 && *s < 1100.0 && v.iter().sum::<f64>() > 1.0)
            .collect();
        let mean = |i: usize| {
            steady.iter().map(|(_, v)| v[i]).sum::<f64>() / steady.len() as f64
        };
        let high = mean(0) + mean(1);
        let low = mean(2) + mean(3);
        assert!(
            high > 1.5 * low,
            "FCFS should favor popular flows: high={high:.2} low={low:.2}"
        );
    }

    #[test]
    fn fig5b_gap_below_bound() {
        let r = fig5b_result();
        let max = r.windows.iter().map(|(_, g)| *g).fold(f64::MIN, f64::max);
        assert!(
            max < r.bound_s,
            "gap {max:.1} exceeded Eq-1 bound {:.1}",
            r.bound_s
        );
        assert!(r.mean_gap_s < r.bound_s / 2.0);
    }
}
