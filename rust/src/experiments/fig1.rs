//! Figure 1: cold-start phase timeline, CPU vs GPU container, for the
//! TensorFlow-inference function (imagenet). The GPU container adds the
//! NVIDIA hook (~1.6 s) and GPU library loading to user init (~3 s of
//! extra latency in the paper's figure).

use crate::container::ColdPhases;
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::catalog::by_name;

pub struct Timeline {
    pub target: &'static str,
    /// (phase name, start s, end s)
    pub segments: Vec<(&'static str, f64, f64)>,
}

pub fn timelines() -> (Timeline, Timeline) {
    let class = by_name("imagenet").unwrap();
    let cpu = ColdPhases::for_class_cpu(class);
    let gpu = ColdPhases::for_class(class);
    let mk = |target, p: &ColdPhases, exec: f64, hook_name| {
        let mut t = 0.0;
        let mut segments = Vec::new();
        for (name, dur) in [
            ("docker-create", p.docker_s),
            (hook_name, p.nvidia_hook_s),
            ("user-code-init", p.user_init_s),
            ("execution", exec),
        ] {
            if dur > 0.0 {
                segments.push((name, t, t + dur));
                t += dur;
            }
        }
        Timeline { target, segments }
    };
    (
        mk("cpu", &cpu, class.cpu_warm_s, "(no hook)"),
        mk("gpu", &gpu, class.gpu_warm_s, "nvidia-hook"),
    )
}

pub fn main() {
    println!("== Figure 1: cold-start timeline (imagenet / TF inference) ==");
    let (cpu, gpu) = timelines();
    let mut t = Table::new(&["target", "phase", "start(s)", "end(s)", "dur(s)"]);
    let mut csv = CsvWriter::create(
        "results/fig1.csv",
        &["target", "phase", "start_s", "end_s"],
    )
    .unwrap();
    for tl in [&cpu, &gpu] {
        for (phase, s, e) in &tl.segments {
            t.row(&[
                tl.target.to_string(),
                phase.to_string(),
                format!("{s:.2}"),
                format!("{e:.2}"),
                format!("{:.2}", e - s),
            ]);
            csv.rowv(&[
                tl.target.to_string(),
                phase.to_string(),
                format!("{s:.3}"),
                format!("{e:.3}"),
            ])
            .unwrap();
        }
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    let init = |tl: &Timeline| -> f64 {
        tl.segments
            .iter()
            .filter(|(n, _, _)| *n != "execution")
            .map(|(_, s, e)| e - s)
            .sum()
    };
    println!(
        "GPU container init {:.2}s vs CPU {:.2}s — +{:.2}s before execution \
         (paper Fig 1: ~3s of nvidia-hook + GPU library loading)",
        init(&gpu),
        init(&cpu),
        init(&gpu) - init(&cpu)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_timeline_has_hook_cpu_does_not() {
        let (cpu, gpu) = timelines();
        assert!(gpu.segments.iter().any(|(n, _, _)| *n == "nvidia-hook"));
        assert!(!cpu.segments.iter().any(|(n, _, _)| *n == "nvidia-hook"));
    }

    #[test]
    fn segments_are_contiguous() {
        let (_, gpu) = timelines();
        for w in gpu.segments.windows(2) {
            assert!((w[0].2 - w[1].1).abs() < 1e-9);
        }
    }

    #[test]
    fn gpu_init_exceeds_cpu_init_by_seconds() {
        let (cpu, gpu) = timelines();
        let init = |tl: &Timeline| {
            tl.segments
                .iter()
                .filter(|(n, _, _)| *n != "execution")
                .map(|(_, s, e)| e - s)
                .sum::<f64>()
        };
        assert!(init(&gpu) - init(&cpu) > 3.0);
    }
}
