//! Table 3: the nine Azure-sampled workloads — offered load (req/s) and
//! measured GPU utilization under the default MQFQ-Sticky configuration.

use crate::plane::PlaneConfig;
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::azure::{self, AzureConfig, TABLE3_NFUNCS, TABLE3_UTIL};

#[derive(Debug, Clone)]
pub struct Row {
    pub trace_id: usize,
    pub n_funcs: usize,
    pub req_per_sec: f64,
    pub measured_util_pct: f64,
    pub paper_util_pct: f64,
}

pub fn rows(duration_s: f64) -> Vec<Row> {
    (0..9)
        .map(|trace_id| {
            let (w, t) = azure::generate(&AzureConfig {
                trace_id,
                duration_s,
                load_scale: 1.0,
            });
            let rps = t.req_per_sec();
            let r = crate::sim::replay(w, &t, PlaneConfig::default());
            Row {
                trace_id,
                n_funcs: TABLE3_NFUNCS[trace_id],
                req_per_sec: rps,
                measured_util_pct: r.mean_util * 100.0,
                paper_util_pct: TABLE3_UTIL[trace_id],
            }
        })
        .collect()
}

pub fn main() {
    println!("== Table 3: Azure trace samples (600 s each) ==");
    let rows = rows(600.0);
    let mut t = Table::new(&["Trace ID", "funcs", "req/s", "util% (measured)", "util% (paper)"]);
    let mut csv = CsvWriter::create(
        "results/table3.csv",
        &["trace_id", "n_funcs", "req_per_sec", "measured_util_pct", "paper_util_pct"],
    )
    .unwrap();
    for r in &rows {
        t.row(&[
            r.trace_id.to_string(),
            r.n_funcs.to_string(),
            format!("{:.2}", r.req_per_sec),
            format!("{:.1}", r.measured_util_pct),
            format!("{:.1}", r.paper_util_pct),
        ]);
        csv.rowv(&[
            r.trace_id.to_string(),
            r.n_funcs.to_string(),
            format!("{:.3}", r.req_per_sec),
            format!("{:.2}", r.measured_util_pct),
            format!("{:.1}", r.paper_util_pct),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_spans_the_paper_band() {
        let rows = rows(300.0);
        assert_eq!(rows.len(), 9);
        // Utilizations should spread over a meaningful band like the
        // paper's 38–80%, and track the per-trace targets loosely.
        let min = rows.iter().map(|r| r.measured_util_pct).fold(f64::MAX, f64::min);
        let max = rows.iter().map(|r| r.measured_util_pct).fold(f64::MIN, f64::max);
        assert!(max - min > 10.0, "no spread: {min}..{max}");
        assert!(max <= 100.0 && min > 5.0);
    }
}
