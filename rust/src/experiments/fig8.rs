//! Figure 8: sensitivity to the scheduling parameters.
//!
//! * 8a — queue over-run T sweep, wall-time VT vs uniform-1.0 VT.
//! * 8b — anticipatory TTL sweep: α × per-function IAT vs fixed global.
//! * 8c — container-pool size vs cold-start %, MQFQ vs FCFS × D.

use crate::plane::PlaneConfig;
use crate::scheduler::policies::PolicyKind;
use crate::scheduler::MqfqConfig;
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::azure::{self, AzureConfig};
use crate::workload::zipf::{self, ZipfConfig};

use super::run;

fn zipf_workload() -> (crate::workload::Workload, crate::workload::Trace) {
    zipf::generate(&ZipfConfig {
        n_funcs: 24,
        total_rate: 2.0,
        duration_s: 600.0,
        seed: 8,
        ..Default::default()
    })
}

// ---------------------------------------------------------------- 8a ---

pub fn fig8a_rows() -> Vec<(f64, bool, f64)> {
    let mut out = Vec::new();
    for &t_overrun in &[0.0, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0] {
        for &wall in &[true, false] {
            let (w, t) = zipf_workload();
            let cfg = PlaneConfig {
                policy: PolicyKind::Mqfq,
                d: 2,
                mqfq: MqfqConfig {
                    t: t_overrun,
                    vt_wall_time: wall,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (s, _) = run(
                &format!("T={t_overrun} {}", if wall { "wall" } else { "1.0" }),
                w,
                &t,
                cfg,
            );
            out.push((t_overrun, wall, s.wavg_latency_s));
        }
    }
    out
}

pub fn fig8a() {
    println!("== Figure 8a: queue over-run (T) sweep ==");
    let rows = fig8a_rows();
    let mut t = Table::new(&["T", "VT=wall-time lat(s)", "VT=1.0 lat(s)"]);
    let mut csv =
        CsvWriter::create("results/fig8a.csv", &["t", "wall_latency_s", "uniform_latency_s"])
            .unwrap();
    for &t_overrun in &[0.0, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0] {
        let wall = rows
            .iter()
            .find(|(tv, w, _)| *tv == t_overrun && *w)
            .unwrap()
            .2;
        let unif = rows
            .iter()
            .find(|(tv, w, _)| *tv == t_overrun && !*w)
            .unwrap()
            .2;
        t.row(&[
            format!("{t_overrun}"),
            format!("{wall:.2}"),
            format!("{unif:.2}"),
        ]);
        csv.rowv(&[
            format!("{t_overrun}"),
            format!("{wall:.4}"),
            format!("{unif:.4}"),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(paper: T=0 ≈2.5× worse; wall-time VT up to 2.7× better than 1.0)");
}

// ---------------------------------------------------------------- 8b ---

/// (label, weighted-avg latency s, mean on-device time s, mean in-shim s)
pub fn fig8b_rows() -> Vec<(String, f64, f64, f64)> {
    let run_one = |label: String, cfg: MqfqConfig| {
        let (w, t) = zipf_workload();
        let plane_cfg = PlaneConfig {
            policy: PolicyKind::Mqfq,
            d: 2,
            mqfq: cfg,
            ..Default::default()
        };
        let (s, r) = run(&label, w, &t, plane_cfg);
        let rec = r.recorder();
        let shim = rec.records.iter().map(|x| x.in_shim_s()).sum::<f64>()
            / rec.records.len().max(1) as f64;
        (label, s.wavg_latency_s, s.mean_exec_s, shim)
    };
    let mut out = Vec::new();
    for &alpha in &[0.0, 0.1, 0.5, 1.0, 2.0, 3.0, 4.0] {
        out.push(run_one(
            format!("α={alpha}"),
            MqfqConfig {
                ttl_alpha: alpha,
                ..Default::default()
            },
        ));
    }
    for &fixed in &[0.1, 1.0, 4.0] {
        out.push(run_one(
            format!("fixed={fixed}s"),
            MqfqConfig {
                fixed_ttl_s: Some(fixed),
                ..Default::default()
            },
        ));
    }
    out
}

pub fn fig8b() {
    println!("== Figure 8b: anticipatory keep-alive TTL sweep ==");
    let rows = fig8b_rows();
    let mut t = Table::new(&["ttl", "avg-lat(s)", "mean-exec(s)", "in-shim(s)"]);
    let mut csv = CsvWriter::create(
        "results/fig8b.csv",
        &["ttl", "wavg_latency_s", "mean_exec_s", "in_shim_s"],
    )
    .unwrap();
    for (label, lat, exec, shim) in &rows {
        t.row(&[
            label.clone(),
            format!("{lat:.2}"),
            format!("{exec:.3}"),
            format!("{shim:.3}"),
        ]);
        csv.rowv(&[
            label.clone(),
            format!("{lat:.4}"),
            format!("{exec:.4}"),
            format!("{shim:.4}"),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(paper: α=0 +50% latency; per-function IAT ~15% better than fixed)");
}

// ---------------------------------------------------------------- 8c ---

pub fn fig8c_rows() -> Vec<(usize, &'static str, usize, f64)> {
    let mut out = Vec::new();
    for &pool in &[4usize, 8, 12, 16, 24, 32] {
        for policy in [PolicyKind::Mqfq, PolicyKind::Fcfs] {
            for d in [1usize, 2] {
                let (w, t) = azure::generate(&AzureConfig {
                    trace_id: 4,
                    duration_s: 600.0,
                    load_scale: 1.0,
                });
                let cfg = PlaneConfig {
                    policy,
                    d,
                    pool_size: pool,
                    ..Default::default()
                };
                let (s, _) = run(
                    &format!("pool={pool} {} D={d}", policy.name()),
                    w,
                    &t,
                    cfg,
                );
                out.push((pool, policy.name(), d, s.cold_ratio * 100.0));
            }
        }
    }
    out
}

pub fn fig8c() {
    println!("== Figure 8c: cold-start % vs container-pool size ==");
    let rows = fig8c_rows();
    let mut t = Table::new(&["pool", "mqfq D=1", "mqfq D=2", "fcfs D=1", "fcfs D=2"]);
    let mut csv = CsvWriter::create(
        "results/fig8c.csv",
        &["pool", "mqfq_d1_cold_pct", "mqfq_d2_cold_pct", "fcfs_d1_cold_pct", "fcfs_d2_cold_pct"],
    )
    .unwrap();
    for &pool in &[4usize, 8, 12, 16, 24, 32] {
        let get = |p: &str, d: usize| {
            rows.iter()
                .find(|(pl, pn, dd, _)| *pl == pool && *pn == p && *dd == d)
                .unwrap()
                .3
        };
        t.row(&[
            pool.to_string(),
            format!("{:.1}", get("mqfq-sticky", 1)),
            format!("{:.1}", get("mqfq-sticky", 2)),
            format!("{:.1}", get("fcfs", 1)),
            format!("{:.1}", get("fcfs", 2)),
        ]);
        csv.rowv(&[
            pool.to_string(),
            format!("{:.2}", get("mqfq-sticky", 1)),
            format!("{:.2}", get("mqfq-sticky", 2)),
            format!("{:.2}", get("fcfs", 1)),
            format!("{:.2}", get("fcfs", 2)),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(paper: MQFQ 2–8% cold across sizes; FCFS ~50% at pool=4)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrun_zero_hurts() {
        let rows = fig8a_rows();
        let at = |t: f64| rows.iter().find(|(tv, w, _)| *tv == t && *w).unwrap().2;
        assert!(
            at(0.0) > 1.5 * at(10.0),
            "T=0 {:.2}s should be ≫ T=10 {:.2}s",
            at(0.0),
            at(10.0)
        );
    }

    #[test]
    fn anticipation_helps() {
        let rows = fig8b_rows();
        let lat = |l: &str| rows.iter().find(|r| r.0 == l).unwrap().1;
        let shim = |l: &str| rows.iter().find(|r| r.0 == l).unwrap().3;
        // α=0 swaps every idle queue's regions out immediately; the
        // re-invocation pays the exposed PCIe transfer (in-shim time),
        // and end-to-end latency must not improve.
        assert!(
            shim("α=0") > 2.0 * shim("α=2"),
            "α=0 in-shim {:.3}s vs α=2 {:.3}s",
            shim("α=0"),
            shim("α=2")
        );
        assert!(
            lat("α=0") >= lat("α=2") * 0.98,
            "α=0 lat {:.2}s vs α=2 {:.2}s",
            lat("α=0"),
            lat("α=2")
        );
    }

    #[test]
    fn mqfq_cold_rate_low_and_below_fcfs_at_small_pools() {
        let rows = fig8c_rows();
        let get = |pool: usize, p: &str, d: usize| {
            rows.iter()
                .find(|(pl, pn, dd, _)| *pl == pool && *pn == p && *dd == d)
                .unwrap()
                .3
        };
        assert!(
            get(4, "mqfq-sticky", 1) < get(4, "fcfs", 1),
            "mqfq {:.1}% vs fcfs {:.1}% at pool=4",
            get(4, "mqfq-sticky", 1),
            get(4, "fcfs", 1)
        );
        assert!(get(32, "mqfq-sticky", 1) < 10.0);
    }
}
