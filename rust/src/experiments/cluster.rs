//! §Fig 9 (beyond the paper): cluster scaling sweep — p50/p99 latency,
//! Jain fairness, and cold-start ratio vs shard count (1–16) and router
//! policy, under a rate-scaled locality-heavy (Zipf 1.5) trace. The
//! point of the subsystem: at scale, the locality-aware [`StickyCh`]
//! router keeps every function's warm containers on its home shard, so
//! its cold-start ratio stays near the single-server floor while the
//! spray routers (round-robin / random) re-pay a cold start on every
//! shard a function touches. Results land in
//! `results/fig9_cluster_scaling.csv` and machine-readable
//! `BENCH_cluster.json` for cross-PR tracking (`scripts/bench_diff.sh`).
//!
//! [`StickyCh`]: crate::cluster::router::StickyCh

use crate::cluster::{ClusterConfig, RouterKind, ALL_ROUTERS};
use crate::metrics::jain_index;
use crate::plane::PlaneConfig;
use crate::sim::{replay_cluster, ClusterReplayResult};
use crate::util::csv::CsvWriter;
use crate::util::json::{self, Json};
use crate::util::stats::percentiles;
use crate::util::table::Table;
use crate::workload::zipf::{self, ZipfConfig};
use crate::workload::{scale_rate, Trace, Workload};

/// Sweep parameters (the bench uses the defaults; tests shrink them).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub shard_counts: Vec<usize>,
    pub routers: Vec<RouterKind>,
    /// Offered load per shard, req/s (weak scaling: total = rate × N).
    pub per_shard_rate: f64,
    pub duration_s: f64,
    pub n_funcs: usize,
    pub seed: u64,
    /// StickyCh bounded-load spill factor.
    pub load_factor: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            shard_counts: vec![1, 2, 4, 8, 16],
            routers: ALL_ROUTERS.to_vec(),
            per_shard_rate: 2.0,
            duration_s: 600.0,
            n_funcs: 24,
            seed: 42,
            load_factor: 1.25,
        }
    }
}

/// One (router, shard count) cell of the sweep.
#[derive(Debug, Clone)]
pub struct ClusterRow {
    pub router: &'static str,
    pub shards: usize,
    pub invocations: usize,
    pub p50_s: f64,
    pub p99_s: f64,
    pub wavg_s: f64,
    pub cold_ratio: f64,
    /// Jain index over per-function mean latencies (1.0 = perfectly fair).
    pub fairness_jain: f64,
    pub mean_util: f64,
    pub makespan_s: f64,
    /// Max per-shard arrival share vs an even split (1.0 = balanced).
    pub routing_imbalance: f64,
    /// StickyCh arrivals routed off their home shard (0 for others).
    pub spills: u64,
}

impl ClusterRow {
    /// Measure one replay into a sweep row (shared by the sweep and the
    /// `cluster` CLI subcommand).
    pub fn measure(router: RouterKind, shards: usize, r: &ClusterReplayResult) -> ClusterRow {
        let rec = r.recorder();
        let lat = rec.latencies_s();
        let pcts = percentiles(&lat, &[50.0, 99.0]);
        let per_fn: Vec<f64> = rec.per_function().iter().map(|a| a.mean_latency_s).collect();
        ClusterRow {
            router: router.name(),
            shards,
            invocations: rec.len(),
            p50_s: pcts[0],
            p99_s: pcts[1],
            wavg_s: rec.weighted_avg_latency_s(),
            cold_ratio: r.cluster.pool_stats().cold_ratio(),
            fairness_jain: jain_index(&per_fn),
            mean_util: r.mean_util,
            makespan_s: crate::types::to_secs(r.makespan),
            routing_imbalance: r.cluster.routing_imbalance(),
            spills: r.cluster.spills(),
        }
    }
}

/// The base single-server trace every cell scales from: Zipf 1.5 over
/// the catalog — the locality-heavy shape (a few dominant functions)
/// where sticky routing has the most to win.
pub fn base_trace(cfg: &SweepConfig) -> (Workload, Trace) {
    zipf::generate(&ZipfConfig {
        n_funcs: cfg.n_funcs,
        total_rate: cfg.per_shard_rate,
        duration_s: cfg.duration_s,
        seed: cfg.seed,
        ..Default::default()
    })
}

/// Run the full sweep: every (shard count, router) cell replays the
/// same base trace rate-scaled to the shard count. Deterministic for a
/// fixed [`SweepConfig`].
pub fn sweep(cfg: &SweepConfig) -> Vec<ClusterRow> {
    let (base_w, base_t) = base_trace(cfg);
    let mut rows = Vec::new();
    for &n in &cfg.shard_counts {
        let mut w = base_w.clone();
        let mut t = base_t.clone();
        scale_rate(&mut w, &mut t, n as f64);
        for &router in &cfg.routers {
            let ccfg = ClusterConfig {
                n_shards: n,
                router,
                plane: PlaneConfig::default(),
                shard_planes: Vec::new(),
                load_factor: cfg.load_factor,
                seed: cfg.seed,
                ..Default::default()
            };
            let r = replay_cluster(w.clone(), &t, ccfg);
            rows.push(ClusterRow::measure(router, n, &r));
        }
    }
    rows
}

/// Machine-readable form of the sweep (`BENCH_cluster.json`).
pub fn report_json(cfg: &SweepConfig, rows: &[ClusterRow]) -> Json {
    let row_json = |r: &ClusterRow| {
        Json::Obj(vec![
            ("router".into(), Json::str(r.router)),
            ("shards".into(), Json::Int(r.shards as i64)),
            ("invocations".into(), Json::Int(r.invocations as i64)),
            ("p50_s".into(), Json::Num(r.p50_s)),
            ("p99_s".into(), Json::Num(r.p99_s)),
            ("wavg_s".into(), Json::Num(r.wavg_s)),
            ("cold_ratio".into(), Json::Num(r.cold_ratio)),
            ("fairness_jain".into(), Json::Num(r.fairness_jain)),
            ("mean_util".into(), Json::Num(r.mean_util)),
            ("makespan_s".into(), Json::Num(r.makespan_s)),
            ("routing_imbalance".into(), Json::Num(r.routing_imbalance)),
            ("spills".into(), Json::Int(r.spills as i64)),
        ])
    };
    Json::Obj(vec![
        ("schema".into(), Json::str("mqfq-bench-cluster/v1")),
        (
            "config".into(),
            Json::Obj(vec![
                ("per_shard_rate".into(), Json::Num(cfg.per_shard_rate)),
                ("duration_s".into(), Json::Num(cfg.duration_s)),
                ("n_funcs".into(), Json::Int(cfg.n_funcs as i64)),
                ("seed".into(), Json::Int(cfg.seed as i64)),
                ("load_factor".into(), Json::Num(cfg.load_factor)),
                ("trace".into(), Json::str("zipf-1.5")),
            ]),
        ),
        ("rows".into(), Json::Arr(rows.iter().map(row_json).collect())),
    ])
}

/// Render the standard comparison table.
pub fn rows_table(rows: &[ClusterRow]) -> Table {
    let mut t = Table::new(&[
        "router",
        "shards",
        "invocations",
        "p50(s)",
        "p99(s)",
        "avg(s)",
        "cold%",
        "jain",
        "util%",
        "imbal",
        "spills",
    ]);
    for r in rows {
        t.row(&[
            r.router.to_string(),
            r.shards.to_string(),
            r.invocations.to_string(),
            format!("{:.3}", r.p50_s),
            format!("{:.3}", r.p99_s),
            format!("{:.3}", r.wavg_s),
            format!("{:.2}", r.cold_ratio * 100.0),
            format!("{:.3}", r.fairness_jain),
            format!("{:.1}", r.mean_util * 100.0),
            format!("{:.2}", r.routing_imbalance),
            r.spills.to_string(),
        ]);
    }
    t
}

fn write_csv(rows: &[ClusterRow]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        "results/fig9_cluster_scaling.csv",
        &[
            "router",
            "shards",
            "invocations",
            "p50_s",
            "p99_s",
            "wavg_s",
            "cold_ratio",
            "fairness_jain",
            "mean_util",
            "makespan_s",
            "routing_imbalance",
            "spills",
        ],
    )?;
    for r in rows {
        w.rowv(&[
            r.router.to_string(),
            r.shards.to_string(),
            r.invocations.to_string(),
            format!("{:.6}", r.p50_s),
            format!("{:.6}", r.p99_s),
            format!("{:.6}", r.wavg_s),
            format!("{:.6}", r.cold_ratio),
            format!("{:.6}", r.fairness_jain),
            format!("{:.6}", r.mean_util),
            format!("{:.3}", r.makespan_s),
            format!("{:.4}", r.routing_imbalance),
            r.spills.to_string(),
        ])?;
    }
    w.flush()
}

/// The locality win the subsystem exists to demonstrate: at every swept
/// shard count ≥ 8, StickyCh's cold-start ratio must undercut both
/// spray routers on the Zipf-skewed trace. Behavioral (not timing), so
/// it gates debug and release runs alike.
pub fn assert_locality_win(rows: &[ClusterRow]) {
    let cell = |router: &str, shards: usize| {
        rows.iter()
            .find(|r| r.router == router && r.shards == shards)
    };
    let mut sizes: Vec<usize> = rows.iter().map(|r| r.shards).filter(|&n| n >= 8).collect();
    sizes.sort_unstable();
    sizes.dedup();
    for n in sizes {
        let (Some(sticky), Some(rr), Some(random)) = (
            cell(RouterKind::StickyCh.name(), n),
            cell(RouterKind::RoundRobin.name(), n),
            cell(RouterKind::Random.name(), n),
        ) else {
            continue; // sweep didn't include all three at this size
        };
        assert!(
            sticky.cold_ratio < rr.cold_ratio,
            "StickyCh cold ratio {:.4} not below round-robin {:.4} at {n} shards",
            sticky.cold_ratio,
            rr.cold_ratio
        );
        assert!(
            sticky.cold_ratio < random.cold_ratio,
            "StickyCh cold ratio {:.4} not below random {:.4} at {n} shards",
            sticky.cold_ratio,
            random.cold_ratio
        );
    }
}

pub fn main() {
    println!("== Fig 9: cluster scaling (shards × router, zipf-1.5, weak scaling) ==");
    let cfg = SweepConfig::default();
    let t0 = std::time::Instant::now();
    let rows = sweep(&cfg);
    print!("{}", rows_table(&rows).render());
    println!("[swept {} cells in {:.2?}]", rows.len(), t0.elapsed());
    match write_csv(&rows) {
        Ok(()) => println!("wrote results/fig9_cluster_scaling.csv"),
        Err(e) => println!("csv not written: {e}"),
    }
    match json::write_file("BENCH_cluster.json", &report_json(&cfg, &rows)) {
        Ok(()) => println!("wrote BENCH_cluster.json"),
        Err(e) => println!("BENCH_cluster.json not written: {e}"),
    }
    assert_locality_win(&rows);
    println!("locality gate: StickyCh cold-start ratio beats spray routers at ≥8 shards");
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small sweep the debug-mode tests can afford (still ≥ 8 shards so
    /// the locality acceptance criterion is exercised for real).
    fn small_cfg() -> SweepConfig {
        SweepConfig {
            shard_counts: vec![1, 8],
            routers: ALL_ROUTERS.to_vec(),
            duration_s: 120.0,
            ..Default::default()
        }
    }

    #[test]
    fn sticky_beats_spray_on_cold_starts_at_8_shards() {
        let rows = sweep(&small_cfg());
        assert_locality_win(&rows);
        // And not vacuously: all four routers actually ran at 8 shards.
        assert_eq!(rows.iter().filter(|r| r.shards == 8).count(), 4);
        for r in &rows {
            assert!(r.invocations > 0, "{} @ {} empty", r.router, r.shards);
        }
    }

    #[test]
    fn sweep_is_deterministic() {
        let cfg = SweepConfig {
            shard_counts: vec![2],
            duration_s: 60.0,
            ..Default::default()
        };
        let a = report_json(&cfg, &sweep(&cfg)).render();
        let b = report_json(&cfg, &sweep(&cfg)).render();
        assert_eq!(a, b, "same seed must produce identical BENCH rows");
    }

    #[test]
    fn report_json_has_the_tracked_fields() {
        let cfg = SweepConfig {
            shard_counts: vec![1],
            routers: vec![RouterKind::StickyCh],
            duration_s: 30.0,
            ..Default::default()
        };
        let rows = sweep(&cfg);
        assert_eq!(rows.len(), 1);
        let doc = report_json(&cfg, &rows).render();
        for key in [
            "\"schema\"",
            "mqfq-bench-cluster/v1",
            "\"rows\"",
            "\"router\"",
            "\"shards\"",
            "\"p50_s\"",
            "\"p99_s\"",
            "\"cold_ratio\"",
            "\"fairness_jain\"",
            "\"routing_imbalance\"",
            "\"spills\"",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
    }

    #[test]
    fn fairness_and_util_are_sane() {
        let cfg = SweepConfig {
            shard_counts: vec![2],
            routers: vec![RouterKind::LeastLoaded],
            duration_s: 60.0,
            ..Default::default()
        };
        let rows = sweep(&cfg);
        let r = &rows[0];
        assert!(r.fairness_jain > 0.0 && r.fairness_jain <= 1.0 + 1e-12);
        assert!(r.mean_util >= 0.0 && r.mean_util <= 1.0);
        assert!(r.p99_s >= r.p50_s);
        assert!(r.routing_imbalance >= 1.0 - 1e-12);
    }
}
