//! §Serving: wall-clock serving-path throughput over real loopback TCP.
//!
//! The dispatch *decision* is microseconds (§Perf), so at cluster scale
//! the serving envelope — wire framing, submit-path locking, executor
//! threading — is what bounds invocations/second. This harness measures
//! that envelope end to end: a multi-threaded load generator drives a
//! model-mode `serve` frontend over real TCP in two loop disciplines:
//!
//! * **Closed loop** — C client threads, each issuing the next invoke
//!   as soon as the previous reply lands (sync, and an async
//!   ticket+wait mix). Measures saturation throughput and per-request
//!   wire latency.
//! * **Open loop** — paced submitters firing async invokes on a fixed
//!   schedule regardless of completions, with paired waiter
//!   connections redeeming tickets concurrently. Measures latency at a
//!   controlled offered rate (the Azure-trace regime: arrivals don't
//!   wait for you).
//!
//! Shapes cover a 1-shard [`crate::server::RtServer`] and a 4-shard
//! sticky [`crate::server::RtCluster`], reporting invokes/s and
//! p50/p99 wire latency per shape, emitting `BENCH_serving.json`
//! (diffable via `scripts/bench_diff.sh`), and gating in release mode:
//! 4-shard sticky throughput must hold ≥ [`SCALE_GATE`] × the 1-shard
//! figure. Set `SERVING_QUICK=1` for a seconds-scale smoke run
//! (CI): smaller volumes, no gates.
//!
//! Two axes added with the epoll serving front end:
//!
//! * **Connection scaling** — the same total invoke volume spread over
//!   100 → 1k → 10k live connections (a fixed driver pool multiplexes
//!   them, so client-side threading stays constant while the *server*
//!   sees the full connection count). The event loop's promise is that
//!   throughput stays flat (within [`CONN_FLAT_GATE`]) across the axis
//!   and serving-side threads stay `shards × workers + O(1)` — both
//!   gated, the thread bound unconditionally (it is not
//!   timing-sensitive).
//! * **Push vs poll** — the async-ticket mix re-run with push
//!   subscriptions (`invoke_push`/`wait_push`: one submit round trip,
//!   completion pushed by the server) against the two-round-trip
//!   ticket+wait baseline; release gate holds push p99 ≤ polling p99.
//!
//! Model time is scaled so far down that modeled service is negligible
//! against the wire path — the numbers isolate the serving envelope,
//! not the GPU model.

use std::net::SocketAddr;
use std::sync::mpsc::channel;
use std::thread;
use std::time::{Duration, Instant};

use crate::api::ApiClient;
use crate::cluster::{ClusterConfig, RouterKind};
use crate::plane::PlaneConfig;
use crate::server::{RtCluster, RtServer};
use crate::util::json::{self, Json};
use crate::util::stats::percentiles;
use crate::workload::catalog::by_name;
use crate::workload::Workload;

/// Release-mode gate: 4-shard sticky closed-loop throughput over the
/// 1-shard figure. Per the ROADMAP bench protocol, the first
/// cargo-capable session tunes this on real numbers if it trips
/// (recording which in CHANGES.md).
pub const SCALE_GATE: f64 = 2.0;

/// Sanity floor on 1-shard sync closed-loop throughput (invokes/s),
/// release mode. Deliberately generous — loopback TCP on any modern
/// machine clears this by orders of magnitude.
pub const MIN_THROUGHPUT: f64 = 1_000.0;

/// Release-mode connection-scaling gate: throughput at the largest
/// connection count must hold ≥ this fraction of the smallest-count
/// row ("flat within 20%").
pub const CONN_FLAT_GATE: f64 = 0.8;

/// O(1) slack on the serving-thread bound: timer + poller + accept-side
/// bookkeeping. The exact expectation is `shards × workers` executors
/// plus one monitor per shard plus timer and poller; the slack absorbs
/// transient runtime threads without hiding a per-connection leak.
pub const THREAD_SLACK: usize = 4;

/// Functions registered for the sweep (clients round-robin over them,
/// so sticky routing spreads load across shard homes).
const N_FUNCS: usize = 16;

/// Model-time scale: modeled delays become sub-microsecond wall time,
/// so measurements isolate the serving envelope.
const SCALE: f64 = 1e-6;

fn serving_workload() -> Workload {
    let mut w = Workload::default();
    let class = by_name("isoneural").expect("catalog has isoneural");
    for i in 0..N_FUNCS {
        w.register(class, i, 1.0);
    }
    w
}

fn func_name(i: usize) -> String {
    format!("isoneural-{}", i % N_FUNCS)
}

/// A running model-mode target; held only for its guard semantics
/// (dropping it stops the server).
#[allow(dead_code)]
enum Target {
    Single(RtServer),
    Cluster(RtCluster),
}

fn start_target(shards: usize) -> (Target, SocketAddr) {
    let w = serving_workload();
    if shards <= 1 {
        let srv = RtServer::new(w, PlaneConfig::default(), None, SCALE).unwrap();
        let addr = srv.serve("127.0.0.1:0").unwrap();
        (Target::Single(srv), addr)
    } else {
        let cfg = ClusterConfig {
            n_shards: shards,
            router: RouterKind::StickyCh,
            plane: PlaneConfig::default(),
            ..Default::default()
        };
        let srv = RtCluster::new(w, cfg, None, SCALE).unwrap();
        let addr = srv.serve("127.0.0.1:0").unwrap();
        (Target::Cluster(srv), addr)
    }
}

/// One measured shape of the sweep.
#[derive(Debug, Clone)]
pub struct ServingRow {
    /// Identity: "sync-closed" | "async-closed" | "push-closed" |
    /// "open" | "conn-scale".
    pub shape: &'static str,
    /// Identity: loop discipline, "closed" | "open".
    pub loop_mode: &'static str,
    pub shards: usize,
    pub clients: usize,
    /// Identity: live server-side connections during the measurement
    /// (== driving clients except on the conn-scale axis, where a
    /// fixed driver pool multiplexes many connections).
    pub connections: usize,
    pub invokes: usize,
    pub wall_s: f64,
    /// Completed invokes per wall second.
    pub throughput: f64,
    /// Wire latency percentiles (ms): request issue → completion reply.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Serving-side thread count measured with every connection open
    /// (0 = not sampled for this shape).
    pub server_threads: usize,
}

fn row(
    shape: &'static str,
    loop_mode: &'static str,
    shards: usize,
    clients: usize,
    wall: Duration,
    lats_ms: Vec<f64>,
) -> ServingRow {
    let wall_s = wall.as_secs_f64().max(1e-9);
    let p = percentiles(&lats_ms, &[50.0, 99.0]);
    ServingRow {
        shape,
        loop_mode,
        shards,
        clients,
        connections: clients,
        invokes: lats_ms.len(),
        wall_s,
        throughput: lats_ms.len() as f64 / wall_s,
        p50_ms: p[0],
        p99_ms: p[1],
        server_threads: 0,
    }
}

/// Closed loop, sync invokes: each client thread drives one connection
/// flat out for `per_client` invokes.
pub fn closed_loop_sync(shards: usize, clients: usize, per_client: usize) -> ServingRow {
    let (_guard, addr) = start_target(shards);
    let t0 = Instant::now();
    let clients_spawned: Vec<_> = (0..clients).map(|c| {
        thread::spawn(move || {
            let mut cl = ApiClient::connect(addr).unwrap();
            let func = func_name(c);
            let mut lats = Vec::with_capacity(per_client);
            for _ in 0..per_client {
                let s = Instant::now();
                cl.invoke(&func, Some(60_000)).unwrap();
                lats.push(s.elapsed().as_secs_f64() * 1e3);
            }
            lats
        })
    })
    .collect();
    let lats = join_all(clients_spawned);
    row("sync-closed", "closed", shards, clients, t0.elapsed(), lats)
}

/// Closed loop, async ticket mix: each iteration submits async and
/// immediately redeems the ticket (two round trips per invocation —
/// the ticket-table path under load).
pub fn closed_loop_async(shards: usize, clients: usize, per_client: usize) -> ServingRow {
    let (_guard, addr) = start_target(shards);
    let t0 = Instant::now();
    let clients_spawned: Vec<_> = (0..clients).map(|c| {
        thread::spawn(move || {
            let mut cl = ApiClient::connect(addr).unwrap();
            let func = func_name(c);
            let mut lats = Vec::with_capacity(per_client);
            for _ in 0..per_client {
                let s = Instant::now();
                let t = cl.invoke_async(&func).unwrap();
                cl.wait(t, Some(60_000)).unwrap();
                lats.push(s.elapsed().as_secs_f64() * 1e3);
            }
            lats
        })
    })
    .collect();
    let lats = join_all(clients_spawned);
    row("async-closed", "closed", shards, clients, t0.elapsed(), lats)
}

/// Closed loop, push-subscribed: each iteration submits with a push
/// subscription and blocks on the server-push completion — one round
/// trip plus a push line, against `async-closed`'s two round trips.
pub fn closed_loop_push(shards: usize, clients: usize, per_client: usize) -> ServingRow {
    let (_guard, addr) = start_target(shards);
    let t0 = Instant::now();
    let clients_spawned: Vec<_> = (0..clients).map(|c| {
        thread::spawn(move || {
            let mut cl = ApiClient::connect(addr).unwrap();
            let func = func_name(c);
            let mut lats = Vec::with_capacity(per_client);
            for _ in 0..per_client {
                let s = Instant::now();
                let t = cl.invoke_push(&func).unwrap();
                cl.wait_push(t).unwrap();
                lats.push(s.elapsed().as_secs_f64() * 1e3);
            }
            lats
        })
    })
    .collect();
    let lats = join_all(clients_spawned);
    row("push-closed", "closed", shards, clients, t0.elapsed(), lats)
}

/// This process's live thread count (`/proc/self/status`).
fn process_threads() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find_map(|l| l.strip_prefix("Threads:"))
                .and_then(|v| v.trim().parse().ok())
        })
        .unwrap_or(0)
}

/// How many driver threads multiplex the conn-scale connection fleet —
/// fixed so client-side parallelism is identical at every point on the
/// axis and only the server-visible connection count varies.
const CONN_DRIVERS: usize = 8;

/// Connection-scaling shape: `connections` live sockets spread over
/// [`CONN_DRIVERS`] driver threads, each driver round-robining sync
/// invokes across its share until the fleet completes
/// `total_invokes`. Every connection is opened (and kept open) before
/// the clock starts, and the serving-side thread count is sampled with
/// the whole fleet connected — the event loop must not have grown a
/// thread per connection.
pub fn conn_scaling(shards: usize, connections: usize, total_invokes: usize) -> ServingRow {
    // 10k sockets need headroom over the default 1024 soft limit; both
    // ends of every loopback connection live in this process.
    crate::server::event_loop::raise_nofile_limit(connections as u64 * 2 + 512);
    let base_threads = process_threads();
    let (_guard, addr) = start_target(shards);
    let drivers = CONN_DRIVERS.min(connections.max(1));
    // Two rendezvous: (1) every connection open, main samples the
    // thread count; (2) drivers released into the measured loop.
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(drivers + 1));
    let handles: Vec<_> = (0..drivers)
        .map(|d| {
            let barrier = std::sync::Arc::clone(&barrier);
            thread::spawn(move || {
                let n_conns = connections / drivers + usize::from(d < connections % drivers);
                let quota = total_invokes / drivers + usize::from(d < total_invokes % drivers);
                let mut conns: Vec<ApiClient> = (0..n_conns)
                    .map(|_| ApiClient::connect(addr).unwrap())
                    .collect();
                barrier.wait(); // fleet fully connected
                barrier.wait(); // thread count sampled; measure
                let mut lats = Vec::with_capacity(quota);
                for k in 0..quota {
                    let c = k % conns.len().max(1);
                    let func = func_name(d + c * CONN_DRIVERS);
                    let s = Instant::now();
                    conns[c].invoke(&func, Some(60_000)).unwrap();
                    lats.push(s.elapsed().as_secs_f64() * 1e3);
                }
                lats
            })
        })
        .collect();
    barrier.wait();
    // Everything above base + drivers belongs to the serving side
    // (executors, monitors, timer, poller) — per-connection threads
    // would show up here.
    let server_threads = process_threads().saturating_sub(base_threads + drivers);
    barrier.wait();
    let t0 = Instant::now();
    let lats = join_all(handles);
    let mut r = row("conn-scale", "closed", shards, drivers, t0.elapsed(), lats);
    r.connections = connections;
    r.server_threads = server_threads;
    r
}

/// Open loop: each client pair is a paced submitter (async invokes on a
/// fixed schedule, never waiting) plus a waiter connection redeeming
/// tickets concurrently in submit order. Latency is submit instant →
/// completion observed over the wire.
pub fn open_loop(
    shards: usize,
    clients: usize,
    rate_per_client: f64,
    per_client: usize,
) -> ServingRow {
    let (_guard, addr) = start_target(shards);
    let t0 = Instant::now();
    let clients_spawned: Vec<_> = (0..clients).map(|c| {
        thread::spawn(move || {
            let (tx, rx) = channel::<(crate::api::Ticket, Instant)>();
            let waiter = thread::spawn(move || {
                let mut w = ApiClient::connect(addr).unwrap();
                let mut lats = Vec::new();
                for (ticket, s) in rx {
                    w.wait(ticket, Some(60_000)).unwrap();
                    lats.push(s.elapsed().as_secs_f64() * 1e3);
                }
                lats
            });
            let mut sub = ApiClient::connect(addr).unwrap();
            let func = func_name(c);
            let interval = Duration::from_secs_f64(1.0 / rate_per_client);
            let start = Instant::now();
            for i in 0..per_client {
                let due = start + interval.mul_f64(i as f64);
                let now = Instant::now();
                if due > now {
                    thread::sleep(due - now);
                }
                let s = Instant::now();
                let ticket = sub.invoke_async(&func).unwrap();
                // Waiter gone ⇒ an earlier wait failed; surface below.
                if tx.send((ticket, s)).is_err() {
                    break;
                }
            }
            drop(tx);
            waiter.join().unwrap()
        })
    })
    .collect();
    let lats = join_all(clients_spawned);
    row("open", "open", shards, clients, t0.elapsed(), lats)
}

/// Join a fully-spawned client fleet (spawn-all-then-join keeps the
/// clients concurrent) and merge their latency samples.
fn join_all(handles: Vec<thread::JoinHandle<Vec<f64>>>) -> Vec<f64> {
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("load-generator client panicked"));
    }
    all
}

/// The full §Serving sweep.
pub struct ServingReport {
    pub rows: Vec<ServingRow>,
    /// 4-shard sticky over 1-shard sync closed-loop throughput — the
    /// scaling headline the release gate holds.
    pub scale_4x1: f64,
    /// Largest-connection-count conn-scale throughput over the
    /// smallest — the "flat across the axis" headline
    /// ([`CONN_FLAT_GATE`] holds this in release mode).
    pub conn_flatness: f64,
}

fn find<'a>(rows: &'a [ServingRow], shape: &str, shards: usize) -> &'a ServingRow {
    rows.iter()
        .find(|r| r.shape == shape && r.shards == shards)
        .expect("sweep row present")
}

/// Run the sweep. `quick` shrinks volumes (and the connection axis) to
/// a seconds-scale smoke (used by CI; timing gates are skipped by the
/// caller in that mode — the thread-bound assertion still runs).
pub fn collect(quick: bool) -> ServingReport {
    let (sync_n, async_n, open_n) = if quick { (50, 30, 40) } else { (2_000, 1_000, 800) };
    let open_rate = if quick { 200.0 } else { 500.0 };
    let (conn_axis, conn_total): (&[usize], usize) = if quick {
        (&[10, 50, 200], 800)
    } else {
        (&[100, 1_000, 10_000], 16_000)
    };
    let mut rows = vec![
        closed_loop_sync(1, 4, sync_n),
        closed_loop_sync(4, 16, sync_n),
        closed_loop_async(1, 4, async_n),
        closed_loop_async(4, 16, async_n),
        closed_loop_push(1, 4, async_n),
        closed_loop_push(4, 16, async_n),
        open_loop(1, 4, open_rate, open_n),
        open_loop(4, 8, open_rate, open_n),
    ];
    for &conns in conn_axis {
        rows.push(conn_scaling(1, conns, conn_total));
    }
    // The thread bound is structural, not timing-sensitive: hold it on
    // every run (quick and debug included). A per-connection thread
    // leak would blow this up by orders of magnitude at 10k.
    let expected =
        crate::server::DEFAULT_WORKERS /* executors, 1 shard */ + 1 /* monitor */ + THREAD_SLACK;
    for r in rows.iter().filter(|r| r.shape == "conn-scale") {
        assert!(
            r.server_threads <= expected,
            "serving threads grew with connections: {} threads at {} conns \
             (bound {expected} = shards*workers + O(1))",
            r.server_threads,
            r.connections
        );
    }
    let scale_4x1 = find(&rows, "sync-closed", 4).throughput
        / find(&rows, "sync-closed", 1).throughput.max(1e-9);
    let conn_rows: Vec<&ServingRow> = rows.iter().filter(|r| r.shape == "conn-scale").collect();
    let conn_flatness = conn_rows.last().expect("conn-scale rows").throughput
        / conn_rows.first().expect("conn-scale rows").throughput.max(1e-9);
    ServingReport {
        rows,
        scale_4x1,
        conn_flatness,
    }
}

/// Machine-readable form of the report (`BENCH_serving.json`).
pub fn report_json(r: &ServingReport) -> Json {
    let rows = r
        .rows
        .iter()
        .map(|row| {
            Json::Obj(vec![
                ("shape".into(), Json::str(row.shape)),
                ("loop".into(), Json::str(row.loop_mode)),
                ("shards".into(), Json::Int(row.shards as i64)),
                ("clients".into(), Json::Int(row.clients as i64)),
                ("connections".into(), Json::Int(row.connections as i64)),
                ("invokes".into(), Json::Int(row.invokes as i64)),
                ("wall_s".into(), Json::Num(row.wall_s)),
                (
                    "throughput_invokes_per_sec".into(),
                    Json::Num(row.throughput),
                ),
                ("p50_ms".into(), Json::Num(row.p50_ms)),
                ("p99_ms".into(), Json::Num(row.p99_ms)),
                (
                    "server_threads".into(),
                    Json::Int(row.server_threads as i64),
                ),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::str("mqfq-bench-serving/v1")),
        ("serving".into(), Json::Arr(rows)),
        (
            "throughput_ratio_4shard_over_1shard".into(),
            Json::Num(r.scale_4x1),
        ),
        (
            "conn_scale_throughput_ratio_max_over_min".into(),
            Json::Num(r.conn_flatness),
        ),
    ])
}

fn print_rows(rows: &[ServingRow]) {
    println!(
        "{:<14} {:>6} {:>8} {:>7} {:>9} {:>12} {:>10} {:>10} {:>8}",
        "shape", "shards", "clients", "conns", "invokes", "invokes/s", "p50(ms)", "p99(ms)",
        "threads"
    );
    for r in rows {
        println!(
            "{:<14} {:>6} {:>8} {:>7} {:>9} {:>12.0} {:>10.3} {:>10.3} {:>8}",
            r.shape,
            r.shards,
            r.clients,
            r.connections,
            r.invokes,
            r.throughput,
            r.p50_ms,
            r.p99_ms,
            r.server_threads
        );
    }
}

pub fn main() {
    let quick = std::env::var("SERVING_QUICK").is_ok();
    println!(
        "== §Serving: wall-clock serving-path throughput{} ==",
        if quick { " (quick)" } else { "" }
    );
    let report = collect(quick);
    print_rows(&report.rows);
    println!(
        "4-shard sticky / 1-shard sync closed-loop throughput: {:.2}x",
        report.scale_4x1
    );
    println!(
        "connection-scaling throughput (largest / smallest conn count): {:.2}x",
        report.conn_flatness
    );
    match json::write_file("BENCH_serving.json", &report_json(&report)) {
        Ok(()) => println!("wrote BENCH_serving.json"),
        Err(e) => println!("BENCH_serving.json not written: {e}"),
    }

    // Release-bench regression gates (debug builds and quick runs are
    // untimed). Tunable on first real numbers per the ROADMAP protocol.
    if !cfg!(debug_assertions) && !quick {
        let one = find(&report.rows, "sync-closed", 1);
        assert!(
            one.throughput >= MIN_THROUGHPUT,
            "1-shard sync closed-loop throughput {:.0}/s below the {MIN_THROUGHPUT:.0}/s floor",
            one.throughput
        );
        assert!(
            report.scale_4x1 >= SCALE_GATE,
            "4-shard sticky throughput only {:.2}x the 1-shard figure (gate {SCALE_GATE:.1}x)",
            report.scale_4x1
        );
        assert!(
            report.conn_flatness >= CONN_FLAT_GATE,
            "throughput at 10k connections fell to {:.2}x the 100-connection figure \
             (gate {CONN_FLAT_GATE:.2}x)",
            report.conn_flatness
        );
        let push = find(&report.rows, "push-closed", 4);
        let poll = find(&report.rows, "async-closed", 4);
        assert!(
            push.p99_ms <= poll.p99_ms,
            "push completion p99 {:.3} ms worse than ticket-polling p99 {:.3} ms",
            push.p99_ms,
            poll.p99_ms
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_smoke_conserves_invocations() {
        // Tiny end-to-end run over real loopback TCP: every issued
        // invoke completes and is measured exactly once.
        let r = closed_loop_sync(1, 2, 10);
        assert_eq!(r.invokes, 20);
        assert_eq!(r.shards, 1);
        assert!(r.throughput > 0.0);
        assert!(r.p50_ms > 0.0 && r.p99_ms >= r.p50_ms);
    }

    #[test]
    fn async_and_open_loops_smoke() {
        let a = closed_loop_async(1, 2, 5);
        assert_eq!(a.invokes, 10);
        let o = open_loop(1, 2, 500.0, 10);
        assert_eq!(o.invokes, 20);
        assert!(o.p99_ms >= o.p50_ms);
    }

    #[test]
    fn push_loop_smoke() {
        let p = closed_loop_push(1, 2, 5);
        assert_eq!(p.invokes, 10);
        assert_eq!(p.shape, "push-closed");
        assert!(p.p99_ms >= p.p50_ms);
    }

    #[test]
    fn conn_scaling_multiplexes_and_conserves() {
        // 12 connections over the fixed driver pool; every invoke of
        // the quota completes exactly once. The thread-count sample is
        // not asserted here — the parallel test harness runs other
        // thread-spawning tests in this process, so the bound is only
        // meaningful in the standalone experiment binary (collect()).
        let r = conn_scaling(1, 12, 48);
        assert_eq!(r.invokes, 48);
        assert_eq!(r.connections, 12);
        assert_eq!(r.shape, "conn-scale");
        assert!(r.clients <= CONN_DRIVERS);
        assert!(r.throughput > 0.0);
    }

    #[test]
    fn report_json_has_identity_and_metric_keys() {
        let report = ServingReport {
            rows: vec![ServingRow {
                shape: "sync-closed",
                loop_mode: "closed",
                shards: 4,
                clients: 16,
                connections: 16,
                invokes: 1000,
                wall_s: 0.5,
                throughput: 2000.0,
                p50_ms: 0.4,
                p99_ms: 1.9,
                server_threads: 0,
            }],
            scale_4x1: 2.5,
            conn_flatness: 0.97,
        };
        let doc = report_json(&report).render();
        for key in [
            "\"schema\"",
            "\"serving\"",
            "\"shape\"",
            "\"loop\"",
            "\"shards\"",
            "\"clients\"",
            "\"connections\"",
            "\"throughput_invokes_per_sec\"",
            "\"p50_ms\"",
            "\"p99_ms\"",
            "\"server_threads\"",
            "\"throughput_ratio_4shard_over_1shard\"",
            "\"conn_scale_throughput_ratio_max_over_min\"",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
    }
}
