//! §Anticipate: ablation sweep over the anticipatory scheduling
//! subsystem — grace periods × same-flow batch dispatch × the online
//! characteristics estimator — on two traces:
//!
//! * **bursty** — phase-shifted on/off bursts over a Zipf population
//!   ([`crate::workload::zipf::generate_bursty`]): idle gaps near the
//!   TTL boundary make grace periods decisive, and on-phases queue
//!   several same-flow invocations so batching has coalescing
//!   opportunities.
//! * **azure** — the Table-3 medium-intensity Azure-style sample
//!   (trace 4), the realism check: anticipation must not regress the
//!   steady trace it was not designed for.
//!
//! Each grid cell runs the full sim replay with telemetry attached and
//! reports latency percentiles, cold ratio, Jain fairness over
//! per-function total service, and the anticipation counters
//! (grace holds, batches, estimator error).
//!
//! Emits `BENCH_anticipate.json` (`mqfq-bench-anticipate/v1`),
//! diffable via `scripts/bench_diff.sh` (identity keys: `name`,
//! `grace`, `batch`, `estimator`). `ANTICIPATE_QUICK=1` shrinks the
//! traces to a seconds-scale smoke run (CI) and skips the gates.
//!
//! Release gate (full run, release build): on the bursty trace, the
//! grace+batch+estimator cell must beat the no-anticipation baseline
//! on p50 latency while holding Jain fairness within 5%.

use std::sync::Arc;

use crate::estimator::AnticipateConfig;
use crate::metrics::fairness::jain_index;
use crate::plane::PlaneConfig;
use crate::telemetry::{self, Telemetry};
use crate::util::json::{self, Json};
use crate::util::stats::percentiles;
use crate::workload::azure::AzureConfig;
use crate::workload::zipf::{BurstyConfig, ZipfConfig};
use crate::workload::{Trace, Workload};

/// Jain fairness of the anticipating cell must stay within this factor
/// of the baseline's (the "equal fairness" half of the gate).
pub const JAIN_GATE: f64 = 0.95;

/// One cell of the ablation grid.
#[derive(Debug, Clone)]
pub struct GridRow {
    /// Identity: trace name ("bursty" | "azure").
    pub trace: &'static str,
    /// Identity: anticipation toggles.
    pub grace: bool,
    pub batch: bool,
    pub estimator: bool,
    pub invocations: usize,
    pub p50_s: f64,
    pub p99_s: f64,
    pub wavg_s: f64,
    pub cold_ratio: f64,
    /// Jain index over per-function total service received.
    pub jain_service: f64,
    pub grace_holds: u64,
    pub batch_dispatches: u64,
    pub batched_invocations: u64,
    /// Median |predicted − actual| exec error, ns (0 = estimator off).
    pub est_error_p50_ns: u64,
}

fn plane_cfg(grace: bool, batch: bool, estimator: bool) -> PlaneConfig {
    let mut cfg = PlaneConfig::default();
    cfg.mqfq.anticipate = AnticipateConfig {
        grace_alpha: if grace { 2.0 } else { 0.0 },
        batch_max: if batch { 4 } else { 1 },
        batch_marginal: 0.6,
        estimator,
    };
    cfg
}

/// Run one grid cell: full sim replay with telemetry attached.
pub fn run_cell(
    trace_name: &'static str,
    workload: &Workload,
    trace: &Trace,
    grace: bool,
    batch: bool,
    estimator: bool,
) -> GridRow {
    let cfg = plane_cfg(grace, batch, estimator);
    let (classes, _) = telemetry::workload_classes(workload);
    let tel = Arc::new(Telemetry::new(&[cfg.n_devices()], &classes));
    let label = format!(
        "{trace_name}/grace={}/batch={}/est={}",
        grace as u8, batch as u8, estimator as u8
    );
    let (s, r) = super::run_traced(&label, workload.clone(), trace, cfg, Some(tel.clone()));
    let rec = r.recorder();
    let p = percentiles(&rec.latencies_s(), &[50.0, 99.0]);
    let service: Vec<f64> = rec
        .per_function()
        .iter()
        .map(|a| a.mean_exec_s * a.invocations as f64)
        .collect();
    let m = tel.registry.shard(0);
    GridRow {
        trace: trace_name,
        grace,
        batch,
        estimator,
        invocations: s.invocations,
        p50_s: p[0],
        p99_s: p[1],
        wavg_s: s.wavg_latency_s,
        cold_ratio: s.cold_ratio,
        jain_service: jain_index(&service),
        grace_holds: m.grace_holds.get(),
        batch_dispatches: m.batch_dispatches.get(),
        batched_invocations: m.batched_invocations.get(),
        est_error_p50_ns: m.est_abs_error_ns.quantile(0.5),
    }
}

/// The bursty stress trace (the gate's subject).
pub fn bursty_trace(quick: bool) -> (Workload, Trace) {
    crate::workload::zipf::generate_bursty(&BurstyConfig {
        base: ZipfConfig {
            n_funcs: if quick { 6 } else { 16 },
            total_rate: if quick { 1.0 } else { 1.5 },
            duration_s: if quick { 90.0 } else { 600.0 },
            seed: 42,
            ..Default::default()
        },
        burst_s: 8.0,
        idle_s: 16.0,
        burst_factor: 6.0,
    })
}

/// The Azure realism trace.
pub fn azure_trace(quick: bool) -> (Workload, Trace) {
    crate::workload::azure::generate(&AzureConfig {
        trace_id: 4,
        duration_s: if quick { 90.0 } else { 600.0 },
        load_scale: 1.0,
    })
}

/// Run the full 2×2×2 grid on both traces.
pub fn collect(quick: bool) -> Vec<GridRow> {
    let mut rows = Vec::new();
    for (name, (w, t)) in [
        ("bursty", bursty_trace(quick)),
        ("azure", azure_trace(quick)),
    ] {
        for mask in 0..8u32 {
            let (grace, batch, est) = (mask & 1 != 0, mask & 2 != 0, mask & 4 != 0);
            rows.push(run_cell(name, &w, &t, grace, batch, est));
        }
    }
    rows
}

/// Machine-readable form (`BENCH_anticipate.json`).
pub fn report_json(rows: &[GridRow]) -> Json {
    let cells = rows
        .iter()
        .map(|r| {
            Json::Obj(vec![
                ("name".into(), Json::str(r.trace)),
                ("grace".into(), Json::Bool(r.grace)),
                ("batch".into(), Json::Bool(r.batch)),
                ("estimator".into(), Json::Bool(r.estimator)),
                ("invocations".into(), Json::Int(r.invocations as i64)),
                ("p50_s".into(), Json::Num(r.p50_s)),
                ("p99_s".into(), Json::Num(r.p99_s)),
                ("wavg_s".into(), Json::Num(r.wavg_s)),
                ("cold_ratio".into(), Json::Num(r.cold_ratio)),
                ("jain_service".into(), Json::Num(r.jain_service)),
                ("grace_holds".into(), Json::Int(r.grace_holds as i64)),
                (
                    "batch_dispatches".into(),
                    Json::Int(r.batch_dispatches as i64),
                ),
                (
                    "batched_invocations".into(),
                    Json::Int(r.batched_invocations as i64),
                ),
                (
                    "est_error_p50_ns".into(),
                    Json::Int(r.est_error_p50_ns as i64),
                ),
            ])
        })
        .collect();
    let mut doc = vec![
        ("schema".into(), Json::str("mqfq-bench-anticipate/v1")),
        ("rows".into(), Json::Arr(cells)),
    ];
    if let Some((base, full)) = gate_cells(rows) {
        doc.push(("gate_baseline_p50_s".into(), Json::Num(base.p50_s)));
        doc.push(("gate_anticipate_p50_s".into(), Json::Num(full.p50_s)));
        doc.push((
            "gate_p50_improved".into(),
            Json::Bool(full.p50_s < base.p50_s),
        ));
        doc.push((
            "gate_jain_held".into(),
            Json::Bool(full.jain_service >= JAIN_GATE * base.jain_service),
        ));
    }
    Json::Obj(doc)
}

/// The gate's two bursty cells: (baseline all-off, all-on).
fn gate_cells(rows: &[GridRow]) -> Option<(&GridRow, &GridRow)> {
    let base = rows
        .iter()
        .find(|r| r.trace == "bursty" && !r.grace && !r.batch && !r.estimator)?;
    let full = rows
        .iter()
        .find(|r| r.trace == "bursty" && r.grace && r.batch && r.estimator)?;
    Some((base, full))
}

pub fn main() {
    let quick = std::env::var("ANTICIPATE_QUICK").is_ok();
    println!(
        "== §Anticipate: grace × batch × estimator ablation{} ==",
        if quick { " (quick)" } else { "" }
    );
    let rows = collect(quick);
    println!(
        "{:<7} {:>5} {:>5} {:>4} {:>7} {:>8} {:>8} {:>6} {:>6} {:>6} {:>7} {:>8}",
        "trace", "grace", "batch", "est", "invs", "p50(s)", "p99(s)", "cold%", "jain",
        "holds", "batches", "est-err"
    );
    for r in &rows {
        println!(
            "{:<7} {:>5} {:>5} {:>4} {:>7} {:>8.3} {:>8.3} {:>6.1} {:>6.3} {:>6} {:>7} {:>7.1}m",
            r.trace,
            r.grace as u8,
            r.batch as u8,
            r.estimator as u8,
            r.invocations,
            r.p50_s,
            r.p99_s,
            r.cold_ratio * 100.0,
            r.jain_service,
            r.grace_holds,
            r.batch_dispatches,
            r.est_error_p50_ns as f64 / 1e6,
        );
    }
    match json::write_file("BENCH_anticipate.json", &report_json(&rows)) {
        Ok(()) => println!("wrote BENCH_anticipate.json"),
        Err(e) => println!("BENCH_anticipate.json not written: {e}"),
    }

    let (base, full) = gate_cells(&rows).expect("grid contains the gate cells");
    println!(
        "gate: bursty p50 {:.3}s (all-on) vs {:.3}s (baseline); jain {:.3} vs {:.3}",
        full.p50_s, base.p50_s, full.jain_service, base.jain_service
    );
    // Sanity in every mode: anticipation must actually engage on the
    // bursty trace — a sweep that never graced or batched proves the
    // wiring broke, not that anticipation doesn't pay.
    assert!(full.grace_holds > 0, "grace never held a flow");
    assert!(full.batched_invocations > 0, "batching never coalesced");
    // Timing gates only where timing is meaningful (release, full run).
    if !cfg!(debug_assertions) && !quick {
        assert!(
            full.p50_s < base.p50_s,
            "anticipation did not improve bursty p50: {:.3}s vs {:.3}s",
            full.p50_s,
            base.p50_s
        );
        assert!(
            full.jain_service >= JAIN_GATE * base.jain_service,
            "anticipation sacrificed fairness: jain {:.3} vs baseline {:.3}",
            full.jain_service,
            base.jain_service
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> (Workload, Trace) {
        crate::workload::zipf::generate_bursty(&BurstyConfig {
            base: ZipfConfig {
                n_funcs: 3,
                total_rate: 1.0,
                duration_s: 40.0,
                seed: 9,
                ..Default::default()
            },
            burst_s: 5.0,
            idle_s: 10.0,
            burst_factor: 6.0,
        })
    }

    #[test]
    fn batching_engages_only_when_enabled() {
        let (w, t) = tiny_trace();
        let off = run_cell("bursty", &w, &t, false, false, false);
        assert_eq!(off.batch_dispatches, 0);
        assert_eq!(off.grace_holds, 0);
        assert_eq!(off.est_error_p50_ns, 0, "no estimator, no error series");
        let on = run_cell("bursty", &w, &t, true, true, true);
        assert_eq!(on.invocations, off.invocations, "same trace replayed");
        assert!(on.batched_invocations > 0, "bursts must coalesce");
    }

    #[test]
    fn report_json_has_identity_and_gate_keys() {
        let row = GridRow {
            trace: "bursty",
            grace: false,
            batch: false,
            estimator: false,
            invocations: 10,
            p50_s: 1.0,
            p99_s: 2.0,
            wavg_s: 1.2,
            cold_ratio: 0.1,
            jain_service: 0.9,
            grace_holds: 0,
            batch_dispatches: 0,
            batched_invocations: 0,
            est_error_p50_ns: 0,
        };
        let mut full = row.clone();
        full.grace = true;
        full.batch = true;
        full.estimator = true;
        full.p50_s = 0.8;
        let doc = report_json(&[row, full]).render();
        for key in [
            "\"schema\"",
            "\"name\"",
            "\"grace\"",
            "\"batch\"",
            "\"estimator\"",
            "\"p50_s\"",
            "\"jain_service\"",
            "\"gate_p50_improved\"",
            "\"gate_jain_held\"",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
        assert!(doc.contains("mqfq-bench-anticipate/v1"));
    }
}
