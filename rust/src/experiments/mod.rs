//! The experiment harness: one function per table/figure of the paper's
//! evaluation (see DESIGN.md §4 for the index). Shared by the bench
//! binaries (`cargo bench --bench <exp>`) and the CLI (`mqfq-sticky exp
//! <exp>`). Every experiment prints a paper-style table and writes a CSV
//! under `results/`.

pub mod ablation;
pub mod anticipate;
pub mod cluster;
pub mod elastic;
pub mod faults;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod hetero;
pub mod perf;
pub mod serving;
pub mod table1;
pub mod table3;

use crate::plane::PlaneConfig;
use crate::sim::ReplayResult;
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::{Trace, Workload};

/// Summary of one replay (the common row unit across experiments).
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub label: String,
    pub invocations: usize,
    pub wavg_latency_s: f64,
    pub mean_exec_s: f64,
    pub p99_latency_s: f64,
    pub cold_ratio: f64,
    pub mean_util: f64,
    pub inter_fn_variance: f64,
    pub makespan_s: f64,
}

/// Run one replay and summarize.
pub fn run(label: &str, workload: Workload, trace: &Trace, cfg: PlaneConfig) -> (RunSummary, ReplayResult) {
    run_traced(label, workload, trace, cfg, None)
}

/// [`run`] with an optional telemetry attachment (the CLI's
/// `replay --trace-out` sink).
pub fn run_traced(
    label: &str,
    workload: Workload,
    trace: &Trace,
    cfg: PlaneConfig,
    tel: Option<std::sync::Arc<crate::telemetry::Telemetry>>,
) -> (RunSummary, ReplayResult) {
    let r = crate::sim::replay_traced(workload, trace, cfg, tel);
    let rec = r.recorder();
    let p99 = crate::util::stats::percentiles(&rec.latencies_s(), &[99.0])[0];
    let summary = RunSummary {
        label: label.to_string(),
        invocations: rec.len(),
        wavg_latency_s: rec.weighted_avg_latency_s(),
        mean_exec_s: rec.mean_exec_s(),
        p99_latency_s: p99,
        cold_ratio: r.plane.pool_stats().cold_ratio(),
        mean_util: r.mean_util,
        inter_fn_variance: rec.inter_function_variance(),
        makespan_s: crate::types::to_secs(r.makespan),
    };
    (summary, r)
}

/// Render a set of run summaries as the standard comparison table.
pub fn summary_table(rows: &[RunSummary]) -> Table {
    let mut t = Table::new(&[
        "config",
        "invocations",
        "avg-lat(s)",
        "p99-lat(s)",
        "exec(s)",
        "cold%",
        "util%",
        "var(fn)",
    ]);
    for s in rows {
        t.row(&[
            s.label.clone(),
            s.invocations.to_string(),
            format!("{:.3}", s.wavg_latency_s),
            format!("{:.3}", s.p99_latency_s),
            format!("{:.3}", s.mean_exec_s),
            format!("{:.1}", s.cold_ratio * 100.0),
            format!("{:.1}", s.mean_util * 100.0),
            format!("{:.1}", s.inter_fn_variance),
        ]);
    }
    t
}

/// Write summaries to `results/<name>.csv`.
pub fn write_summary_csv(name: &str, rows: &[RunSummary]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        format!("results/{name}.csv"),
        &[
            "config",
            "invocations",
            "wavg_latency_s",
            "p99_latency_s",
            "mean_exec_s",
            "cold_ratio",
            "mean_util",
            "inter_fn_variance",
            "makespan_s",
        ],
    )?;
    for s in rows {
        w.rowv(&[
            s.label.clone(),
            s.invocations.to_string(),
            format!("{:.6}", s.wavg_latency_s),
            format!("{:.6}", s.p99_latency_s),
            format!("{:.6}", s.mean_exec_s),
            format!("{:.6}", s.cold_ratio),
            format!("{:.6}", s.mean_util),
            format!("{:.6}", s.inter_fn_variance),
            format!("{:.3}", s.makespan_s),
        ])?;
    }
    w.flush()
}

/// Experiment registry for the CLI.
pub const ALL: &[(&str, fn())] = &[
    ("table1", table1::main),
    ("fig1", fig1::main),
    ("fig3", fig3::main),
    ("fig4", fig4::main),
    ("table3", table3::main),
    ("fig5a", fig5::fig5a),
    ("fig5b", fig5::fig5b),
    ("fig5c", fig5::fig5c),
    ("fig6a", fig6::fig6a),
    ("fig6b", fig6::fig6b),
    ("fig6c", fig6::fig6c),
    ("fig7a", fig7::fig7a),
    ("fig7b", fig7::fig7b),
    ("fig7c", fig7::fig7c),
    ("fig8a", fig8::fig8a),
    ("fig8b", fig8::fig8b),
    ("fig8c", fig8::fig8c),
    ("ablation", ablation::main),
    ("perf", perf::main),
    ("cluster", cluster::main),
    ("hetero", hetero::main),
    ("serving", serving::main),
    ("elastic", elastic::main),
    ("anticipate", anticipate::main),
    ("faults", faults::main),
];

/// Look up an experiment by name.
pub fn by_name(name: &str) -> Option<fn()> {
    ALL.iter().find(|(n, _)| *n == name).map(|(_, f)| *f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_every_table_and_figure() {
        let names: Vec<&str> = ALL.iter().map(|(n, _)| *n).collect();
        for expect in [
            "table1", "fig1", "fig3", "fig4", "table3", "fig5a", "fig5b", "fig5c",
            "fig6a", "fig6b", "fig6c", "fig7a", "fig7b", "fig7c", "fig8a", "fig8b",
            "fig8c", "ablation", "perf", "cluster", "hetero", "serving", "elastic",
            "anticipate", "faults",
        ] {
            assert!(names.contains(&expect), "{expect} missing");
        }
        assert!(by_name("table1").is_some());
        assert!(by_name("nope").is_none());
    }
}
