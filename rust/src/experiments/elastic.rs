//! §Elastic: membership storm — drain/kill/join under load, in both
//! clocks.
//!
//! Two phases share one script (shrink the fleet, fail a shard, heal):
//!
//! * **Sim storm** — a deterministic virtual-time replay against the
//!   [`crate::cluster::Cluster`]: Poisson-ish arrivals over a 4-shard
//!   sticky ring while a shard drains, another is killed mid-flight,
//!   and both rejoin. Completion events are epoch-stamped exactly like
//!   the wall-clock server's timer items; events from a killed epoch
//!   are dropped and counted, never delivered. The phase gate is
//!   *invocation conservation*: every arrival either completed or was
//!   reported lost by the kill — nothing vanishes, nothing is counted
//!   twice (the graveyard recorder keeps killed shards' finished work).
//!
//! * **TCP storm** — the wall-clock acceptance run over real loopback
//!   TCP against a 4-shard model-mode [`crate::server::RtCluster`]:
//!   measure a pre-kill latency baseline, submit an async burst, kill
//!   one shard while its work is in flight (waiters already blocked on
//!   doomed tickets must wake with `shard-lost` *immediately*, not at
//!   their deadline), heal, and then measure recovery batches until
//!   p99 returns under [`RECOVERY_GATE`] × the pre-kill p99. Every
//!   ticket's fate is recorded; the release gates hold zero
//!   deadline-expired waits, ticket-fate conservation at quiescence,
//!   and recovery within [`MAX_RECOVERY_BATCHES`] batches.
//!
//! Emits `BENCH_elastic.json` (`mqfq-bench-elastic/v1`) with the sim
//! phase table and the TCP latency/cold-ratio timeline; diffable via
//! `scripts/bench_diff.sh`. `ELASTIC_QUICK=1` shrinks volumes to a
//! seconds-scale smoke run (CI) and skips the gates.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::net::SocketAddr;
use std::thread;
use std::time::{Duration, Instant};

use crate::api::{ApiClient, ApiError, Ticket};
use crate::cluster::{Cluster, ClusterConfig, RouterKind};
use crate::plane::PlaneConfig;
use crate::server::RtCluster;
use crate::types::{secs, InvocationId, Nanos, MS};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats::percentiles;
use crate::workload::catalog::by_name;
use crate::workload::Workload;

/// Release gate: post-heal p99 must return under this multiple of the
/// pre-kill p99 within [`MAX_RECOVERY_BATCHES`] recovery batches.
pub const RECOVERY_GATE: f64 = 1.5;

/// Recovery window: batches measured after the heal before the gate
/// gives up.
pub const MAX_RECOVERY_BATCHES: usize = 20;

/// Wait deadline for every storm ticket (ms). The no-hung-waiters gate
/// asserts every wait resolves well inside one such window.
pub const STORM_DEADLINE_MS: u64 = 60_000;

const N_FUNCS: usize = 12;

fn elastic_workload() -> Workload {
    let mut w = Workload::default();
    let class = by_name("isoneural").expect("catalog has isoneural");
    for i in 0..N_FUNCS {
        w.register(class, i, 1.0);
    }
    // One deliberately slow class so the TCP storm has work in flight
    // to strand (fft's cold boot is seconds of model time).
    w.register(by_name("fft").expect("catalog has fft"), 0, 1.0);
    w
}

fn func_name(i: usize) -> String {
    format!("isoneural-{}", i % N_FUNCS)
}

// ---------------------------------------------------------------------
// Sim storm: deterministic virtual-time membership script.
// ---------------------------------------------------------------------

/// One phase of the sim storm script.
#[derive(Debug, Clone)]
pub struct SimPhaseRow {
    /// Identity: "baseline" | "drain" | "kill" | "heal".
    pub phase: &'static str,
    pub arrivals: usize,
    /// Completions delivered during this phase (any shard).
    pub completed: usize,
    /// Invocations lost by a kill in this phase (queued + in flight on
    /// the killed shard — reported, never silently requeued).
    pub lost: usize,
    /// Epoch-stale completion events dropped in this phase.
    pub stale_drops: usize,
    /// Cold starts incurred during this phase.
    pub cold: u64,
}

/// Sim storm result: the phase table plus the conservation totals.
pub struct SimStorm {
    pub rows: Vec<SimPhaseRow>,
    pub total_arrivals: usize,
    pub total_completed: usize,
    pub total_lost: usize,
    pub total_stale: usize,
    /// `arrivals == completed + lost` after the final drain-down.
    pub conserved: bool,
    /// Graveyard check: merged recorder length equals completions even
    /// though a shard's plane was discarded mid-run.
    pub records_match: bool,
}

/// Pending completion event: `(due, seq, shard, inv, epoch)` — the
/// sim-side twin of the server's epoch-stamped timer items.
type SimEvent = (Nanos, u64, usize, InvocationId, u64);

struct SimDriver {
    cluster: Cluster,
    heap: BinaryHeap<Reverse<SimEvent>>,
    seq: u64,
    now: Nanos,
    completed: usize,
    stale: usize,
}

impl SimDriver {
    fn push_dispatches(&mut self, ds: Vec<crate::sim::ShardDispatch>) {
        for sd in ds {
            let epoch = self.cluster.shard_epoch(sd.shard);
            self.seq += 1;
            self.heap.push(Reverse((
                sd.dispatch.complete_at,
                self.seq,
                sd.shard,
                sd.dispatch.inv,
                epoch,
            )));
        }
    }

    /// Deliver every event due at/before `t`, dropping stale epochs.
    fn drain_until(&mut self, t: Nanos) {
        loop {
            match self.heap.peek() {
                Some(Reverse(ev)) if ev.0 <= t => {}
                _ => break,
            }
            let Reverse((due, _, shard, inv, epoch)) = self.heap.pop().unwrap();
            self.now = self.now.max(due);
            if self.cluster.shard_epoch(shard) != epoch {
                self.stale += 1;
                continue;
            }
            let (rec, ds) = self.cluster.on_complete(shard, inv, due);
            if rec.is_some() {
                self.completed += 1;
            }
            self.push_dispatches(ds);
        }
    }

    fn arrive(&mut self, func: usize) {
        let (_, _, ds) = self
            .cluster
            .on_arrival(crate::types::FuncId(func as u32), self.now);
        self.push_dispatches(ds);
    }

    /// Run the cluster dry: deliver remaining events, nudging stalled
    /// queues with monitor ticks (bounded — a conservation bug fails
    /// loudly instead of spinning).
    fn drain_all(&mut self) {
        let mut guard = 0;
        while self.cluster.pending() + self.cluster.in_flight() > 0 {
            guard += 1;
            assert!(guard < 1_000_000, "sim storm failed to drain");
            if let Some(due) = self.heap.peek().map(|Reverse(ev)| ev.0) {
                self.drain_until(due);
            } else {
                self.now += 200 * MS;
                let ds = self.cluster.on_monitor_tick(self.now);
                self.push_dispatches(ds);
            }
        }
    }
}

/// Run the deterministic sim membership storm.
pub fn sim_storm(quick: bool) -> SimStorm {
    let per_phase = if quick { 150 } else { 1_500 };
    let cluster = Cluster::new(
        elastic_workload(),
        ClusterConfig {
            n_shards: 4,
            router: RouterKind::StickyCh,
            plane: PlaneConfig::default(),
            ..Default::default()
        },
    );
    let mut d = SimDriver {
        cluster,
        heap: BinaryHeap::new(),
        seq: 0,
        now: 0,
        completed: 0,
        stale: 0,
    };
    let mut rng = Rng::new(0xE1A5_71C5);
    let mut rows = Vec::new();
    let mut lost_total = 0usize;
    // Membership script: steady state → drain shard 1 → kill shard 2
    // mid-flight → heal both.
    for phase in ["baseline", "drain", "kill", "heal"] {
        let (completed0, stale0) = (d.completed, d.stale);
        let cold0 = d.cluster.pool_stats().cold;
        let mut lost = 0usize;
        match phase {
            "drain" => d.cluster.drain_shard(1).unwrap(),
            "kill" => {
                lost = d.cluster.kill_shard(2).unwrap();
                lost_total += lost;
            }
            "heal" => {
                d.cluster.join_shard(1).unwrap();
                d.cluster.join_shard(2).unwrap();
            }
            _ => {}
        }
        for i in 0..per_phase {
            // Mean ~40 ms inter-arrival keeps all shards busy without
            // unbounded queue growth.
            d.now += secs(rng.range(0.005, 0.075));
            d.drain_until(d.now);
            d.arrive(i % N_FUNCS);
        }
        rows.push(SimPhaseRow {
            phase,
            arrivals: per_phase,
            completed: d.completed - completed0,
            lost,
            stale_drops: d.stale - stale0,
            cold: d.cluster.pool_stats().cold - cold0,
        });
    }
    d.drain_all();
    // Attribute the tail drain's completions to the final phase.
    let drained: usize = d.completed - rows.iter().map(|r| r.completed).sum::<usize>();
    if let Some(last) = rows.last_mut() {
        last.completed += drained;
    }
    let total_arrivals = rows.iter().map(|r| r.arrivals).sum();
    let conserved = total_arrivals == d.completed + lost_total;
    let records_match = d.cluster.merged_recorder().len() == d.completed;
    SimStorm {
        rows,
        total_arrivals,
        total_completed: d.completed,
        total_lost: lost_total,
        total_stale: d.stale,
        conserved,
        records_match,
    }
}

// ---------------------------------------------------------------------
// TCP storm: wall-clock acceptance run over real loopback sockets.
// ---------------------------------------------------------------------

/// One measured latency batch of the TCP timeline.
#[derive(Debug, Clone)]
pub struct TcpBatchRow {
    /// Identity: "pre-kill" | "post-heal".
    pub phase: &'static str,
    /// Identity: batch index within the phase.
    pub window: usize,
    pub invokes: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Cold starts incurred during this batch.
    pub cold: u64,
}

/// Ticket fates of the kill storm (every submitted ticket has one).
#[derive(Debug, Clone, Default)]
pub struct StormFates {
    pub completed: usize,
    pub shard_lost: usize,
    pub deadline_expired: usize,
    pub other: usize,
}

pub struct TcpStorm {
    pub timeline: Vec<TcpBatchRow>,
    pub fates: StormFates,
    /// Longest single wait observed (ms) — the zero-hung-waiters
    /// evidence, bounded far under [`STORM_DEADLINE_MS`].
    pub max_wait_ms: f64,
    /// Wake latency of the parked waiter that was blocked on a doomed
    /// ticket when the kill landed (ms).
    pub doomed_wake_ms: f64,
    /// How many of the four pre-kill parked waiters resolved to
    /// `shard-lost` (RR places them one per shard, so exactly 1).
    pub parked_lost: usize,
    pub pre_p99_ms: f64,
    /// Best post-heal p99 over pre-kill p99.
    pub recovery_ratio: f64,
    /// Batches after the heal until p99 first passed the gate
    /// (`None` = never inside the window).
    pub recovered_after: Option<usize>,
    /// Server-side ticket-fate conservation at quiescence.
    pub conserved: bool,
    pub accepted: u64,
    pub completed: u64,
    pub failed: u64,
    pub stale_drops: u64,
}

/// One closed-loop sync batch over `clients` connections; returns the
/// latency samples (ms) and the cold starts the batch incurred.
fn batch(addr: SocketAddr, clients: usize, per_client: usize, cold0: &mut u64) -> (Vec<f64>, u64) {
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            thread::spawn(move || {
                let mut cl = ApiClient::connect(addr).unwrap();
                let mut lats = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let func = func_name(c * per_client + i);
                    let s = Instant::now();
                    cl.invoke(&func, Some(STORM_DEADLINE_MS)).unwrap();
                    lats.push(s.elapsed().as_secs_f64() * 1e3);
                }
                lats
            })
        })
        .collect();
    let mut lats = Vec::new();
    for h in handles {
        lats.extend(h.join().expect("batch client panicked"));
    }
    let mut probe = ApiClient::connect(addr).unwrap();
    let s = probe.stats().unwrap();
    probe.quit();
    let cold_now = (s.cold_ratio * s.invocations as f64).round() as u64;
    let delta = cold_now.saturating_sub(*cold0);
    *cold0 = cold_now;
    (lats, delta)
}

fn batch_row(
    phase: &'static str,
    window: usize,
    lats: &[f64],
    cold: u64,
) -> TcpBatchRow {
    let p = percentiles(lats, &[50.0, 99.0]);
    TcpBatchRow {
        phase,
        window,
        invokes: lats.len(),
        p50_ms: p[0],
        p99_ms: p[1],
        cold,
    }
}

/// Run the wall-clock kill storm. Scale keeps fft's modeled cold boot
/// around tens of real milliseconds so the burst is still in flight
/// when the kill lands.
pub fn tcp_storm(quick: bool) -> TcpStorm {
    let (batch_per_client, storm_n, batches) = if quick { (8, 24, 2) } else { (40, 96, 4) };
    let clients = 4;
    let cfg = ClusterConfig {
        n_shards: 4,
        router: RouterKind::RoundRobin,
        plane: PlaneConfig::default(),
        ..Default::default()
    };
    let srv = RtCluster::new(elastic_workload(), cfg, None, 0.02).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();
    let mut timeline = Vec::new();
    let mut cold0 = 0u64;

    // Pre-kill baseline.
    let mut pre = Vec::new();
    for w in 0..batches {
        let (lats, cold) = batch(addr, clients, batch_per_client, &mut cold0);
        timeline.push(batch_row("pre-kill", w, &lats, cold));
        pre.extend(lats);
    }
    let pre_p99 = percentiles(&pre, &[99.0])[0];

    // Async burst of slow work (fft cold boots ≈ 48 ms wall here), so
    // the kill strands real in-flight invocations. RR spreads the
    // burst evenly; shard 1 holds ~a quarter of it.
    let mut sub = ApiClient::connect(addr).unwrap();
    let tickets: Vec<Ticket> = (0..storm_n)
        .map(|_| sub.invoke_async("fft-0").unwrap())
        .collect();
    // Four waiters park on the first four tickets *before* the kill.
    // RR places four consecutive tickets on all four shards exactly
    // once (whatever the cursor offset), so exactly one parked waiter
    // is blocked on the doomed shard — it must wake with `shard-lost`
    // immediately, not at its deadline.
    let parked: Vec<_> = tickets[..4]
        .iter()
        .map(|&t| {
            thread::spawn(move || {
                let mut w = ApiClient::connect(addr).unwrap();
                let t0 = Instant::now();
                let r = w.wait(t, Some(STORM_DEADLINE_MS));
                (r, t0.elapsed().as_secs_f64() * 1e3)
            })
        })
        .collect();
    thread::sleep(Duration::from_millis(10));
    let m = sub.kill(1).expect("kill shard 1");
    assert_eq!(m.shards[1].epoch, 1);
    let mut fates = StormFates::default();
    let mut parked_lost = 0usize;
    let mut doomed_wake_ms = 0f64;
    let mut max_wait_ms = 0f64;
    for h in parked {
        let (r, ms) = h.join().expect("parked waiter panicked");
        max_wait_ms = max_wait_ms.max(ms);
        match r {
            Err(ApiError::ShardLost { .. }) => {
                parked_lost += 1;
                fates.shard_lost += 1;
                doomed_wake_ms = doomed_wake_ms.max(ms);
            }
            Ok(_) => fates.completed += 1,
            Err(ApiError::DeadlineExceeded { .. }) => fates.deadline_expired += 1,
            Err(_) => fates.other += 1,
        }
    }
    // Every remaining ticket resolves to exactly one fate, each wait
    // bounded by one deadline window.
    let waits: Vec<_> = tickets[4..]
        .chunks(((storm_n - 4) / clients).max(1))
        .map(|chunk| {
            let chunk = chunk.to_vec();
            thread::spawn(move || {
                let mut w = ApiClient::connect(addr).unwrap();
                let mut out = Vec::new();
                for t in chunk {
                    let s = Instant::now();
                    let r = w.wait(t, Some(STORM_DEADLINE_MS));
                    out.push((r, s.elapsed().as_secs_f64() * 1e3));
                }
                out
            })
        })
        .collect();
    for h in waits {
        for (r, ms) in h.join().expect("storm waiter panicked") {
            max_wait_ms = max_wait_ms.max(ms);
            match r {
                Ok(_) => fates.completed += 1,
                Err(ApiError::ShardLost { .. }) => fates.shard_lost += 1,
                Err(ApiError::DeadlineExceeded { .. }) => fates.deadline_expired += 1,
                Err(_) => fates.other += 1,
            }
        }
    }

    // Heal and measure recovery until p99 re-enters the gate.
    sub.join(1).expect("rejoin shard 1");
    let mut recovery_best = f64::INFINITY;
    let mut recovered_after = None;
    for w in 0..MAX_RECOVERY_BATCHES {
        let (lats, cold) = batch(addr, clients, batch_per_client, &mut cold0);
        let row = batch_row("post-heal", w, &lats, cold);
        recovery_best = recovery_best.min(row.p99_ms);
        timeline.push(row);
        if recovery_best <= RECOVERY_GATE * pre_p99 {
            recovered_after = Some(w + 1);
            break;
        }
        if quick && w >= 1 {
            break;
        }
    }
    let recovery_ratio = recovery_best / pre_p99.max(1e-9);

    // Quiescent conservation snapshot.
    let deadline = Instant::now() + Duration::from_secs(30);
    let m = loop {
        let m = sub.membership().expect("membership");
        if m.conserved_at_quiescence() || Instant::now() > deadline {
            break m;
        }
        thread::sleep(Duration::from_millis(10));
    };
    sub.quit();
    TcpStorm {
        timeline,
        fates,
        max_wait_ms,
        doomed_wake_ms,
        parked_lost,
        pre_p99_ms: pre_p99,
        recovery_ratio,
        recovered_after,
        conserved: m.conserved_at_quiescence(),
        accepted: m.accepted,
        completed: m.completed,
        failed: m.failed,
        stale_drops: m.stale_drops,
    }
}

// ---------------------------------------------------------------------
// Report.
// ---------------------------------------------------------------------

pub struct ElasticReport {
    pub sim: SimStorm,
    pub tcp: TcpStorm,
}

pub fn collect(quick: bool) -> ElasticReport {
    ElasticReport {
        sim: sim_storm(quick),
        tcp: tcp_storm(quick),
    }
}

/// Machine-readable form (`BENCH_elastic.json`).
pub fn report_json(r: &ElasticReport) -> Json {
    let sim_rows = r
        .sim
        .rows
        .iter()
        .map(|row| {
            Json::Obj(vec![
                ("phase".into(), Json::str(row.phase)),
                ("arrivals".into(), Json::Int(row.arrivals as i64)),
                ("completed".into(), Json::Int(row.completed as i64)),
                ("lost".into(), Json::Int(row.lost as i64)),
                ("stale_drops".into(), Json::Int(row.stale_drops as i64)),
                ("cold".into(), Json::Int(row.cold as i64)),
            ])
        })
        .collect();
    let tcp_rows = r
        .tcp
        .timeline
        .iter()
        .map(|row| {
            Json::Obj(vec![
                ("phase".into(), Json::str(row.phase)),
                ("window".into(), Json::Int(row.window as i64)),
                ("invokes".into(), Json::Int(row.invokes as i64)),
                ("p50_ms".into(), Json::Num(row.p50_ms)),
                ("p99_ms".into(), Json::Num(row.p99_ms)),
                ("cold".into(), Json::Int(row.cold as i64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::str("mqfq-bench-elastic/v1")),
        ("sim_phases".into(), Json::Arr(sim_rows)),
        (
            "sim_conserved".into(),
            Json::Bool(r.sim.conserved && r.sim.records_match),
        ),
        ("sim_lost".into(), Json::Int(r.sim.total_lost as i64)),
        ("sim_stale_drops".into(), Json::Int(r.sim.total_stale as i64)),
        ("tcp_timeline".into(), Json::Arr(tcp_rows)),
        (
            "tcp_fates".into(),
            Json::Obj(vec![
                ("completed".into(), Json::Int(r.tcp.fates.completed as i64)),
                ("shard_lost".into(), Json::Int(r.tcp.fates.shard_lost as i64)),
                (
                    "deadline_expired".into(),
                    Json::Int(r.tcp.fates.deadline_expired as i64),
                ),
                ("other".into(), Json::Int(r.tcp.fates.other as i64)),
            ]),
        ),
        ("tcp_conserved".into(), Json::Bool(r.tcp.conserved)),
        ("tcp_accepted".into(), Json::Int(r.tcp.accepted as i64)),
        ("tcp_completed".into(), Json::Int(r.tcp.completed as i64)),
        ("tcp_failed".into(), Json::Int(r.tcp.failed as i64)),
        ("tcp_max_wait_ms".into(), Json::Num(r.tcp.max_wait_ms)),
        ("tcp_doomed_wake_ms".into(), Json::Num(r.tcp.doomed_wake_ms)),
        ("tcp_parked_lost".into(), Json::Int(r.tcp.parked_lost as i64)),
        ("tcp_pre_p99_ms".into(), Json::Num(r.tcp.pre_p99_ms)),
        ("tcp_recovery_ratio".into(), Json::Num(r.tcp.recovery_ratio)),
        (
            "tcp_recovered_after_batches".into(),
            Json::Int(r.tcp.recovered_after.map_or(-1, |b| b as i64)),
        ),
        ("tcp_stale_drops".into(), Json::Int(r.tcp.stale_drops as i64)),
    ])
}

pub fn main() {
    let quick = std::env::var("ELASTIC_QUICK").is_ok();
    println!(
        "== §Elastic: membership storm (drain/kill/join under load){} ==",
        if quick { " (quick)" } else { "" }
    );
    let report = collect(quick);

    println!(
        "{:<10} {:>9} {:>10} {:>6} {:>12} {:>6}",
        "phase", "arrivals", "completed", "lost", "stale-drops", "cold"
    );
    for r in &report.sim.rows {
        println!(
            "{:<10} {:>9} {:>10} {:>6} {:>12} {:>6}",
            r.phase, r.arrivals, r.completed, r.lost, r.stale_drops, r.cold
        );
    }
    println!(
        "sim: {} arrivals = {} completed + {} lost (conserved: {}, records: {})",
        report.sim.total_arrivals,
        report.sim.total_completed,
        report.sim.total_lost,
        report.sim.conserved,
        report.sim.records_match,
    );
    let t = &report.tcp;
    println!(
        "tcp: fates completed={} shard-lost={} deadline={} other={} (conserved: {})",
        t.fates.completed,
        t.fates.shard_lost,
        t.fates.deadline_expired,
        t.fates.other,
        t.conserved
    );
    println!(
        "tcp: doomed waiter woke in {:.1} ms; max wait {:.1} ms (deadline {} ms)",
        t.doomed_wake_ms, t.max_wait_ms, STORM_DEADLINE_MS
    );
    println!(
        "tcp: pre-kill p99 {:.2} ms; recovery ratio {:.2}x after {:?} batches",
        t.pre_p99_ms, t.recovery_ratio, t.recovered_after
    );
    match json::write_file("BENCH_elastic.json", &report_json(&report)) {
        Ok(()) => println!("wrote BENCH_elastic.json"),
        Err(e) => println!("BENCH_elastic.json not written: {e}"),
    }

    // Correctness invariants hold in every mode — they are the point of
    // the harness, not a perf gate.
    assert!(report.sim.conserved, "sim storm lost invocations");
    assert!(report.sim.records_match, "graveyard dropped records");
    assert!(t.conserved, "tcp ticket fates do not conserve");
    assert_eq!(t.fates.deadline_expired, 0, "a waiter hung to its deadline");
    assert_eq!(t.fates.other, 0, "unexpected ticket fate");
    // Timing gates only where timing is meaningful (release, full run).
    if !cfg!(debug_assertions) && !quick {
        assert!(
            t.fates.shard_lost > 0,
            "storm stranded nothing — kill landed after the burst drained"
        );
        assert_eq!(
            t.parked_lost, 1,
            "expected exactly one of the four parked waiters on the killed shard"
        );
        assert!(
            t.max_wait_ms < STORM_DEADLINE_MS as f64,
            "a wait consumed its whole deadline window"
        );
        assert!(
            t.recovered_after.is_some(),
            "p99 never re-entered {RECOVERY_GATE}x of pre-kill within \
             {MAX_RECOVERY_BATCHES} batches (ratio {:.2})",
            t.recovery_ratio
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_storm_conserves_invocations_and_records() {
        let s = sim_storm(true);
        assert_eq!(s.rows.len(), 4);
        assert!(s.conserved, "arrivals {} != completed {} + lost {}",
            s.total_arrivals, s.total_completed, s.total_lost);
        assert!(s.records_match);
        // The kill phase actually lost mid-flight work, and its parked
        // events were dropped as stale rather than delivered.
        let kill = s.rows.iter().find(|r| r.phase == "kill").unwrap();
        assert!(kill.lost > 0, "kill phase stranded nothing");
        assert!(s.total_stale > 0, "no stale event was ever dropped");
    }

    #[test]
    fn report_json_has_identity_and_gate_keys() {
        let r = ElasticReport {
            sim: SimStorm {
                rows: vec![SimPhaseRow {
                    phase: "baseline",
                    arrivals: 10,
                    completed: 10,
                    lost: 0,
                    stale_drops: 0,
                    cold: 3,
                }],
                total_arrivals: 10,
                total_completed: 10,
                total_lost: 0,
                total_stale: 0,
                conserved: true,
                records_match: true,
            },
            tcp: TcpStorm {
                timeline: vec![TcpBatchRow {
                    phase: "pre-kill",
                    window: 0,
                    invokes: 32,
                    p50_ms: 0.5,
                    p99_ms: 1.5,
                    cold: 4,
                }],
                fates: StormFates {
                    completed: 24,
                    shard_lost: 8,
                    ..Default::default()
                },
                max_wait_ms: 120.0,
                doomed_wake_ms: 3.0,
                parked_lost: 1,
                pre_p99_ms: 1.5,
                recovery_ratio: 1.1,
                recovered_after: Some(2),
                conserved: true,
                accepted: 32,
                completed: 24,
                failed: 8,
                stale_drops: 8,
            },
        };
        let doc = report_json(&r).render();
        for key in [
            "\"schema\"",
            "\"sim_phases\"",
            "\"phase\"",
            "\"window\"",
            "\"tcp_timeline\"",
            "\"tcp_fates\"",
            "\"shard_lost\"",
            "\"deadline_expired\"",
            "\"tcp_conserved\"",
            "\"tcp_recovery_ratio\"",
            "\"tcp_doomed_wake_ms\"",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
        assert!(doc.contains("mqfq-bench-elastic/v1"));
    }
}
