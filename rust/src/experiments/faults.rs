//! §Faults: device-level fault-tolerance storm — seeded GPU failure,
//! transient exec faults, poison tenants, and overload shedding, in
//! both clocks.
//!
//! Four storms share the seeded fault oracle (same seed ⇒ same faults,
//! either clock; see [`crate::fault`]):
//!
//! * **Device storm (sim)** — a deterministic virtual-time replay with
//!   a scheduled mid-run GPU failure and recovery plus a background
//!   transient-fault rate. Latency is windowed by *arrival* time
//!   (warmup / pre / fail / recover / recovered); the release gate
//!   holds the recovered window's p99 under [`RECOVERY_GATE`] × the
//!   pre-fault p99. Exactly-once is the standing invariant: every
//!   arrival either completed or resolved to a terminal retry-exhausted
//!   fate — nothing vanishes, nothing double-completes.
//!
//! * **Breaker storm (sim)** — one poison tenant (100 % exec-fault
//!   rate) among eight healthy tenants, driven through the serving
//!   admission gate ([`crate::plane::ControlPlane::try_admit`]). The
//!   breaker must trip Open, quarantine the tenant, and re-probe after
//!   the cooldown (half-open); the gate holds Jain fairness across the
//!   healthy tenants at [`JAIN_GATE`] × an identical no-poison run.
//!
//! * **Shed storm (sim)** — the same admission gate under 2× offered
//!   load with deadline-aware shedding calibrated from an uncontended
//!   run. The gate holds the *admitted* p99 within [`SHED_GATE`] × the
//!   uncontended p99; an unprotected 2× run is reported alongside to
//!   show the queue blow-up shedding prevents.
//!
//! * **TCP storm (wall clock)** — the acceptance run over real
//!   loopback TCP against a 2-shard model-mode
//!   [`crate::server::RtCluster`] whose planes carry the fault plan:
//!   a pre-fault latency baseline, an async burst in flight when a GPU
//!   drops on every shard, transparent server-side retries (clients
//!   see `done`, or `exec-failed` after the budget — never a hang),
//!   and a post-recovery baseline holding the same [`RECOVERY_GATE`].
//!   Fault/retry counters are scraped back over the Prometheus wire.
//!
//! Emits `BENCH_faults.json` (`mqfq-bench-faults/v1`) with rows keyed
//! by `fault`/`breaker`/`shed` identities; diffable via
//! `scripts/bench_diff.sh`. `FAULTS_QUICK=1` shrinks volumes to a
//! seconds-scale smoke run (CI) and skips the timing gates.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::net::SocketAddr;
use std::thread;
use std::time::{Duration, Instant};

use crate::api::{ApiClient, ApiError, MetricsFormat, Ticket};
use crate::cluster::{ClusterConfig, RouterKind};
use crate::fault::{AdmitError, BreakerConfig, FaultConfig, FaultStats, ShedConfig};
use crate::gpu::{MultiplexMode, V100};
use crate::metrics::{jain_index, Recorder};
use crate::plane::{ControlPlane, Dispatch, PlaneConfig};
use crate::server::RtCluster;
use crate::sim::replay;
use crate::types::{secs, InvocationId, Nanos, DurNanos};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats::percentiles;
use crate::workload::catalog::by_name;
use crate::workload::trace::TraceEvent;
use crate::workload::{Trace, Workload};

/// Release gate: the post-recovery p99 (sim window / TCP batches) must
/// stay under this multiple of the pre-fault p99.
pub const RECOVERY_GATE: f64 = 1.5;

/// Release gate: healthy-tenant Jain fairness under a quarantined
/// poison tenant must stay at this fraction of the no-poison run.
pub const JAIN_GATE: f64 = 0.95;

/// Release gate: admitted p99 under shedding at 2× offered load must
/// stay within this multiple of the uncontended p99.
pub const SHED_GATE: f64 = 2.0;

/// Wait deadline for every TCP storm ticket (ms); the exactly-once
/// evidence is that every wait resolves well inside one such window.
pub const STORM_DEADLINE_MS: u64 = 60_000;

/// Healthy tenants in the breaker storm (the poison tenant is the
/// extra function with id [`N_TENANTS`]).
pub const N_TENANTS: usize = 8;

fn fault_workload(n_funcs: usize) -> Workload {
    let mut w = Workload::default();
    let class = by_name("isoneural").expect("catalog has isoneural");
    for i in 0..n_funcs {
        w.register(class, i, 1.0);
    }
    w
}

/// Open-loop storm trace: jittered arrivals around `mean_iat_s`,
/// round-robin across `n_funcs` tenants, until `duration_s`.
fn storm_trace(seed: u64, n_funcs: usize, mean_iat_s: f64, duration_s: f64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut t = Trace::default();
    let mut at = 0.0f64;
    let mut i = 0usize;
    while at < duration_s {
        at += rng.range(0.2 * mean_iat_s, 1.8 * mean_iat_s);
        t.events.push(TraceEvent {
            at: secs(at),
            func: crate::types::FuncId((i % n_funcs) as u32),
        });
        i += 1;
    }
    t.sort();
    t
}

fn p50_p99_ms(lats_s: &[f64]) -> (f64, f64) {
    let p = percentiles(lats_s, &[50.0, 99.0]);
    (p[0] * 1e3, p[1] * 1e3)
}

// ---------------------------------------------------------------------
// Device storm: scheduled GPU failure + recovery under transient rate.
// ---------------------------------------------------------------------

/// One arrival-time window of the device storm.
#[derive(Debug, Clone)]
pub struct DevicePhaseRow {
    /// Identity: "warmup" | "pre" | "fail" | "recover" | "recovered".
    pub phase: &'static str,
    pub completed: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

pub struct DeviceStorm {
    pub rows: Vec<DevicePhaseRow>,
    pub arrivals: usize,
    pub completed: usize,
    /// Terminal retry-exhausted fates (the only permitted loss mode).
    pub exec_failed: usize,
    /// `arrivals == completed + exec_failed` at quiescence.
    pub conserved: bool,
    pub stats: FaultStats,
    /// The scheduled recovery put every device back.
    pub fleet_healed: bool,
    /// p99(recovered) / p99(pre).
    pub recovery_ratio: f64,
}

/// Run the deterministic device-failure storm through the virtual-time
/// engine: 4 GPUs, one drops a third of the way in and rejoins at two
/// thirds, with a 5 % transient-fault rate throughout.
pub fn device_storm(quick: bool) -> DeviceStorm {
    // Full-run horizon is sized so the first-ever cold boots (~10 s
    // model time x 9 tenants over 4 GPUs ≈ 22 s of boot debt, drained
    // by ≈ t=35 s) are fully behind the warmup window before the "pre"
    // baseline starts at dur/3.
    let dur = if quick { 12.0 } else { 240.0 };
    let (warm_at, fail_at, heal_at) = (dur / 6.0, dur / 3.0, 2.0 * dur / 3.0);
    let late_at = (heal_at + dur) / 2.0;
    let n_funcs = 9;
    let t = storm_trace(0xFA17_0001, n_funcs, 0.02, dur);
    let mut cfg = PlaneConfig::uniform(4, V100, MultiplexMode::Plain);
    cfg.mqfq.anticipate.estimator = true;
    cfg.faults = Some(FaultConfig {
        seed: 0xFA17_0001,
        transient_rate: 0.05,
        retry_budget: 3,
        device_failures: vec![(secs(fail_at), crate::types::GpuId(0))],
        device_recoveries: vec![(secs(heal_at), crate::types::GpuId(0))],
        ..Default::default()
    });
    let arrivals = t.len();
    let mut r = replay(fault_workload(n_funcs), &t, cfg);
    let fates = r.plane.drain_fault_fates();

    let windows: [(&'static str, f64, f64); 5] = [
        ("warmup", 0.0, warm_at),
        ("pre", warm_at, fail_at),
        ("fail", fail_at, heal_at),
        ("recover", heal_at, late_at),
        ("recovered", late_at, f64::INFINITY),
    ];
    let mut rows = Vec::new();
    for (phase, lo, hi) in windows {
        let lats: Vec<f64> = r
            .recorder()
            .records
            .iter()
            .filter(|rec| {
                let a = crate::types::to_secs(rec.arrived);
                a >= lo && a < hi
            })
            .map(|rec| rec.latency_s())
            .collect();
        let (p50_ms, p99_ms) = p50_p99_ms(&lats);
        rows.push(DevicePhaseRow {
            phase,
            completed: lats.len(),
            p50_ms,
            p99_ms,
        });
    }
    let pre = rows[1].p99_ms.max(1e-9);
    let recovery_ratio = rows[4].p99_ms / pre;
    let completed = r.recorder().len();
    DeviceStorm {
        rows,
        arrivals,
        completed,
        exec_failed: fates.len(),
        conserved: completed + fates.len() == arrivals,
        stats: r.plane.fault_stats(),
        fleet_healed: r.plane.live_devices() == 4,
        recovery_ratio,
    }
}

// ---------------------------------------------------------------------
// Admission-aware sim driver (breaker + shed storms): the serving
// layer's try_admit gate in front of the usual virtual-time loop.
// ---------------------------------------------------------------------

struct AdmitDriver {
    plane: ControlPlane,
    /// Pending completions: `(due, seq, inv, attempt)`.
    heap: BinaryHeap<Reverse<(Nanos, u64, InvocationId, u32)>>,
    seq: u64,
    now: Nanos,
    tick_period: DurNanos,
    next_tick: Nanos,
    arrivals: usize,
    quarantined: usize,
    shed: usize,
}

impl AdmitDriver {
    fn new(w: Workload, cfg: PlaneConfig) -> Self {
        let tick_period = cfg.monitor_period.max(1);
        Self {
            plane: ControlPlane::new(w, cfg),
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
            tick_period,
            next_tick: tick_period,
            arrivals: 0,
            quarantined: 0,
            shed: 0,
        }
    }

    fn push(&mut self, ds: Vec<Dispatch>) {
        for d in ds {
            self.seq += 1;
            self.heap
                .push(Reverse((d.complete_at, self.seq, d.inv, d.attempt)));
        }
    }

    /// Deliver every completion and monitor tick due at/before `t`, in
    /// time order (ticks win ties so watchdog/maintenance runs before
    /// same-instant completions, mirroring the wall-clock monitor).
    fn drain_until(&mut self, t: Nanos) {
        loop {
            let head = self.heap.peek().map(|Reverse(e)| e.0);
            let tick = self.next_tick;
            let next = match head {
                Some(h) => h.min(tick),
                None => tick,
            };
            if next > t {
                break;
            }
            self.now = self.now.max(next);
            if tick <= head.unwrap_or(Nanos::MAX) {
                let ds = self.plane.on_monitor_tick(tick);
                self.push(ds);
                self.next_tick = tick + self.tick_period;
            } else {
                let Reverse((due, _, inv, attempt)) = self.heap.pop().unwrap();
                let (_, ds) = self.plane.on_complete_attempt(inv, attempt, due);
                self.push(ds);
            }
        }
    }

    /// One arrival through the serving admission gate.
    fn arrive(&mut self, func: crate::types::FuncId, at: Nanos) {
        self.drain_until(at);
        self.now = self.now.max(at);
        self.arrivals += 1;
        match self.plane.try_admit(func, self.now) {
            Ok(()) => {
                let (_, ds) = self.plane.on_arrival(func, self.now);
                self.push(ds);
            }
            Err(AdmitError::Quarantined { .. }) => self.quarantined += 1,
            Err(AdmitError::Overloaded { .. }) => self.shed += 1,
        }
    }

    /// Run the plane dry (bounded — a conservation bug fails loudly).
    fn drain_all(&mut self) {
        let mut guard = 0;
        while self.plane.pending() + self.plane.in_flight() > 0 {
            guard += 1;
            assert!(guard < 1_000_000, "fault storm failed to drain");
            let t = match self.heap.peek() {
                Some(&Reverse((due, ..))) => due,
                None => self.next_tick,
            };
            self.drain_until(t);
        }
    }

    fn run(&mut self, trace: &Trace) {
        for ev in &trace.events {
            self.arrive(ev.func, ev.at);
        }
        self.drain_all();
    }
}

/// Jain fairness over the healthy tenants' mean latencies.
fn healthy_jain(rec: &Recorder) -> f64 {
    let per: Vec<f64> = rec
        .per_function()
        .into_iter()
        .filter(|a| (a.func.0 as usize) < N_TENANTS)
        .map(|a| a.mean_latency_s)
        .collect();
    jain_index(&per)
}

// ---------------------------------------------------------------------
// Breaker storm: poison tenant vs circuit breaker.
// ---------------------------------------------------------------------

pub struct BreakerStorm {
    pub arrivals: usize,
    pub completed: usize,
    /// Poison invocations that burned their whole retry budget.
    pub exec_failed: usize,
    /// Admissions rejected by the open breaker.
    pub quarantined: usize,
    pub stats: FaultStats,
    /// Healthy-tenant Jain with no poison tenant misbehaving.
    pub jain_baseline: f64,
    /// Healthy-tenant Jain with the poison tenant quarantined.
    pub jain_poison: f64,
    /// `jain_poison / jain_baseline` (the [`JAIN_GATE`] metric).
    pub jain_ratio: f64,
    pub conserved: bool,
}

/// Run the poison-tenant storm twice — no-poison baseline, then the
/// poison run — through the admission-aware driver.
pub fn breaker_storm(quick: bool) -> BreakerStorm {
    let dur = if quick { 20.0 } else { 120.0 };
    let n_funcs = N_TENANTS + 1;
    let t = storm_trace(0xFA17_0002, n_funcs, 0.015, dur);
    let breaker = BreakerConfig {
        window: 16,
        trip_threshold: 0.5,
        min_samples: 4,
        cooldown: secs(if quick { 4.0 } else { 15.0 }),
        probes: 2,
    };
    let mk_cfg = |poison: Vec<(crate::types::FuncId, f64)>| {
        let mut cfg = PlaneConfig::uniform(4, V100, MultiplexMode::Plain);
        cfg.mqfq.anticipate.estimator = true;
        cfg.faults = Some(FaultConfig {
            seed: 0xFA17_0002,
            poison,
            retry_budget: 2,
            breaker: Some(breaker.clone()),
            ..Default::default()
        });
        cfg
    };

    let mut base = AdmitDriver::new(fault_workload(n_funcs), mk_cfg(Vec::new()));
    base.run(&t);
    let jain_baseline = healthy_jain(&base.plane.recorder);

    let poison_func = crate::types::FuncId(N_TENANTS as u32);
    let mut d = AdmitDriver::new(fault_workload(n_funcs), mk_cfg(vec![(poison_func, 1.0)]));
    d.run(&t);
    let fates = d.plane.drain_fault_fates();
    let jain_poison = healthy_jain(&d.plane.recorder);

    let completed = d.plane.recorder.len();
    BreakerStorm {
        arrivals: d.arrivals,
        completed,
        exec_failed: fates.len(),
        quarantined: d.quarantined,
        stats: d.plane.fault_stats(),
        jain_baseline,
        jain_poison,
        jain_ratio: jain_poison / jain_baseline.max(1e-9),
        conserved: completed + fates.len() + d.quarantined + d.shed == d.arrivals,
    }
}

// ---------------------------------------------------------------------
// Shed storm: deadline-aware admission at 2x offered load.
// ---------------------------------------------------------------------

/// One shed-storm configuration row.
#[derive(Debug, Clone)]
pub struct ShedRow {
    /// Identity: "uncontended" | "shed-2x" | "noshed-2x".
    pub shed: &'static str,
    pub arrivals: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// Admitted completions arriving after the warmup window.
    pub measured: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

pub struct ShedStorm {
    pub rows: Vec<ShedRow>,
    /// Deadline the shed run was calibrated to (seconds).
    pub deadline_s: f64,
    /// p99(shed-2x) / p99(uncontended) — the [`SHED_GATE`] metric.
    pub p99_ratio: f64,
    /// p99(noshed-2x) / p99(uncontended): what the gate prevents.
    pub unprotected_ratio: f64,
    pub shed_count: usize,
    pub conserved: bool,
}

/// Run the overload trio: uncontended 1×, unprotected 2×, and shed 2×
/// (deadline calibrated from the uncontended run's post-warmup p99).
pub fn shed_storm(quick: bool) -> ShedStorm {
    // Cold-boot debt at 1x load (6 tenants x ~10 s boots on 2 GPUs)
    // drains by roughly t=85 s, so the measurement window opens at
    // dur/2 and the full horizon is long enough to leave a clean
    // uncontended baseline behind it.
    let dur = if quick { 15.0 } else { 240.0 };
    let warm = dur / 2.0;
    let n_funcs = 6;
    // 2 GPUs serve isoneural at roughly 77/s; 18 ms mean inter-arrival
    // is ~0.7x capacity, 9 ms is ~1.4x — a sustained 2x offered load.
    let t1 = storm_trace(0xFA17_0003, n_funcs, 0.018, dur);
    let t2 = storm_trace(0xFA17_0003, n_funcs, 0.009, dur);

    let base_cfg = || {
        let mut cfg = PlaneConfig::uniform(2, V100, MultiplexMode::Plain);
        cfg.mqfq.anticipate.estimator = true;
        cfg
    };
    let measure = |rec: &Recorder| -> (usize, f64, f64) {
        let lats: Vec<f64> = rec
            .records
            .iter()
            .filter(|r| crate::types::to_secs(r.arrived) >= warm)
            .map(|r| r.latency_s())
            .collect();
        let (p50, p99) = p50_p99_ms(&lats);
        (lats.len(), p50, p99)
    };

    // Uncontended reference (no fault plan at all).
    let mut unc = AdmitDriver::new(fault_workload(n_funcs), base_cfg());
    unc.run(&t1);
    let (m0, p50_0, p99_0) = measure(&unc.plane.recorder);

    // Unprotected 2x: same plane, double the offered load, no shed.
    let mut raw = AdmitDriver::new(fault_workload(n_funcs), base_cfg());
    raw.run(&t2);
    let (m1, p50_1, p99_1) = measure(&raw.plane.recorder);

    // Shed 2x: deadline calibrated to the uncontended p99. The quick
    // horizon is too short to outrun the cold boots, so its calibration
    // base is junk — pin a small deadline there instead (quick runs
    // assert structure, not ratios, and a tight deadline guarantees the
    // 2x run actually sheds).
    let deadline_s = if quick {
        0.25
    } else {
        (0.8 * p99_0 / 1e3).max(0.05)
    };
    let mut cfg = base_cfg();
    cfg.faults = Some(FaultConfig {
        seed: 0xFA17_0003,
        shed: Some(ShedConfig {
            deadline_s,
            enter: 1.0,
            exit: 0.7,
            retry_after_ms: 250,
        }),
        ..Default::default()
    });
    let mut sh = AdmitDriver::new(fault_workload(n_funcs), cfg);
    sh.run(&t2);
    let (m2, p50_2, p99_2) = measure(&sh.plane.recorder);

    let rows = vec![
        ShedRow {
            shed: "uncontended",
            arrivals: unc.arrivals,
            admitted: unc.arrivals,
            rejected: 0,
            measured: m0,
            p50_ms: p50_0,
            p99_ms: p99_0,
        },
        ShedRow {
            shed: "noshed-2x",
            arrivals: raw.arrivals,
            admitted: raw.arrivals,
            rejected: 0,
            measured: m1,
            p50_ms: p50_1,
            p99_ms: p99_1,
        },
        ShedRow {
            shed: "shed-2x",
            arrivals: sh.arrivals,
            admitted: sh.arrivals - sh.shed,
            rejected: sh.shed,
            measured: m2,
            p50_ms: p50_2,
            p99_ms: p99_2,
        },
    ];
    let conserved = sh.plane.recorder.len() + sh.shed == sh.arrivals;
    ShedStorm {
        rows,
        deadline_s,
        p99_ratio: p99_2 / p99_0.max(1e-9),
        unprotected_ratio: p99_1 / p99_0.max(1e-9),
        shed_count: sh.shed,
        conserved,
    }
}

// ---------------------------------------------------------------------
// TCP storm: wall-clock fault plan over real loopback sockets.
// ---------------------------------------------------------------------

pub struct TcpFaultStorm {
    pub pre_p99_ms: f64,
    pub post_p99_ms: f64,
    /// p99(post-recovery) / p99(pre-fault).
    pub recovery_ratio: f64,
    /// Async burst tickets in flight across the device failure.
    pub burst: usize,
    pub done: usize,
    pub exec_failed: usize,
    /// Any other fate (must be zero: exactly-once means every ticket
    /// resolves to done or exec-failed, never a hang or a loss).
    pub other: usize,
    pub max_wait_ms: f64,
    /// Scraped from the Prometheus wire after the storm.
    pub faults_device: u64,
    pub faults_transient: u64,
    pub retries: u64,
    pub conserved: bool,
    pub accepted: u64,
    pub completed: u64,
    pub failed: u64,
}

/// Sum a Prometheus counter family across its labeled series.
fn prom_sum(body: &str, family: &str) -> u64 {
    body.lines()
        .filter(|l| l.starts_with(family))
        .filter_map(|l| l.rsplit(' ').next()?.parse::<f64>().ok())
        .sum::<f64>() as u64
}

/// One closed-loop sync batch; returns latency samples (ms) and the
/// count of budget-exhausted `exec-failed` replies (tolerated — they
/// are resolutions, not hangs).
fn tcp_batch(addr: SocketAddr, clients: usize, per_client: usize) -> (Vec<f64>, usize) {
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            thread::spawn(move || {
                let mut cl = ApiClient::connect(addr).unwrap();
                let mut lats = Vec::with_capacity(per_client);
                let mut failed = 0usize;
                for i in 0..per_client {
                    let func = format!("isoneural-{}", (c * per_client + i) % N_TENANTS);
                    let s = Instant::now();
                    match cl.invoke(&func, Some(STORM_DEADLINE_MS)) {
                        Ok(_) => lats.push(s.elapsed().as_secs_f64() * 1e3),
                        Err(ApiError::ExecFailed { .. }) => failed += 1,
                        Err(e) => panic!("tcp batch: unexpected error {e:?}"),
                    }
                }
                (lats, failed)
            })
        })
        .collect();
    let mut lats = Vec::new();
    let mut failed = 0;
    for h in handles {
        let (l, f) = h.join().expect("batch client panicked");
        lats.extend(l);
        failed += f;
    }
    (lats, failed)
}

/// Run the wall-clock fault storm: transient faults throughout, one
/// GPU per shard drops at 0.9 s and rejoins at 2.1 s while an async
/// burst is in flight.
pub fn tcp_storm(quick: bool) -> TcpFaultStorm {
    let (per_client, burst_n, batches) = if quick { (6, 16, 2) } else { (25, 64, 3) };
    let clients = 4;
    let fail_at = Duration::from_millis(900);
    let heal_at = Duration::from_millis(2100);
    let mut plane = PlaneConfig::uniform(2, V100, MultiplexMode::Plain);
    plane.faults = Some(FaultConfig {
        seed: 0xFA17_0004,
        transient_rate: 0.15,
        retry_budget: 4,
        device_failures: vec![(secs(0.9), crate::types::GpuId(0))],
        device_recoveries: vec![(secs(2.1), crate::types::GpuId(0))],
        ..Default::default()
    });
    let mut w = fault_workload(N_TENANTS);
    // One slow class so the burst is still in flight when the GPU dies
    // (fft's cold boot is seconds of model time; ~50 ms wall here).
    w.register(by_name("fft").expect("catalog has fft"), 0, 1.0);
    let cfg = ClusterConfig {
        n_shards: 2,
        router: RouterKind::RoundRobin,
        plane,
        ..Default::default()
    };
    let srv = RtCluster::new(w, cfg, None, 0.02).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();
    let t0 = Instant::now();

    // Warm wave (cold boots excluded from the baseline), then the
    // pre-fault baseline batches.
    let _ = tcp_batch(addr, clients, N_TENANTS.div_ceil(clients));
    let mut pre = Vec::new();
    let mut exec_failed = 0usize;
    for _ in 0..batches {
        let (lats, f) = tcp_batch(addr, clients, per_client);
        pre.extend(lats);
        exec_failed += f;
    }
    let pre_p99 = percentiles(&pre, &[99.0])[0];

    // Async burst of slow work timed to be in flight at the failure.
    if let Some(gap) = (fail_at.saturating_sub(Duration::from_millis(150)))
        .checked_sub(t0.elapsed())
    {
        thread::sleep(gap);
    }
    let mut sub = ApiClient::connect(addr).unwrap();
    let tickets: Vec<Ticket> = (0..burst_n)
        .map(|_| sub.invoke_async("fft-0").unwrap())
        .collect();

    // Every burst ticket resolves exactly once, bounded far under one
    // deadline window — the failed device's work is re-queued (forced
    // cold) and retried transparently.
    let mut done = 0usize;
    let mut other = 0usize;
    let mut max_wait_ms = 0f64;
    let waits: Vec<_> = tickets
        .chunks(burst_n.div_ceil(clients).max(1))
        .map(|chunk| {
            let chunk = chunk.to_vec();
            thread::spawn(move || {
                let mut cl = ApiClient::connect(addr).unwrap();
                let mut out = Vec::new();
                for t in chunk {
                    let s = Instant::now();
                    let r = cl.wait(t, Some(STORM_DEADLINE_MS));
                    out.push((r, s.elapsed().as_secs_f64() * 1e3));
                }
                out
            })
        })
        .collect();
    for h in waits {
        for (r, ms) in h.join().expect("storm waiter panicked") {
            max_wait_ms = max_wait_ms.max(ms);
            match r {
                Ok(_) => done += 1,
                Err(ApiError::ExecFailed { .. }) => exec_failed += 1,
                Err(_) => other += 1,
            }
        }
    }

    // Past the recovery (plus one monitor tick of slack), re-warm the
    // rejoined device and measure the post-recovery baseline.
    if let Some(gap) = (heal_at + Duration::from_millis(300)).checked_sub(t0.elapsed()) {
        thread::sleep(gap);
    }
    let _ = tcp_batch(addr, clients, N_TENANTS.div_ceil(clients));
    let mut post = Vec::new();
    for _ in 0..batches {
        let (lats, f) = tcp_batch(addr, clients, per_client);
        post.extend(lats);
        exec_failed += f;
    }
    let post_p99 = percentiles(&post, &[99.0])[0];

    // Quiescent conservation + the fault counters over the wire.
    let deadline = Instant::now() + Duration::from_secs(30);
    let m = loop {
        let m = sub.membership().expect("membership");
        if m.conserved_at_quiescence() || Instant::now() > deadline {
            break m;
        }
        thread::sleep(Duration::from_millis(10));
    };
    let prom = sub.metrics(MetricsFormat::Prom).expect("metrics");
    sub.quit();

    TcpFaultStorm {
        pre_p99_ms: pre_p99,
        post_p99_ms: post_p99,
        recovery_ratio: post_p99 / pre_p99.max(1e-9),
        burst: burst_n,
        done,
        exec_failed,
        other,
        max_wait_ms,
        faults_device: prom_sum(&prom, "mqfq_faults_device_total"),
        faults_transient: prom_sum(&prom, "mqfq_faults_transient_total"),
        retries: prom_sum(&prom, "mqfq_retries_total"),
        conserved: m.conserved_at_quiescence(),
        accepted: m.accepted,
        completed: m.completed,
        failed: m.failed,
    }
}

// ---------------------------------------------------------------------
// Report.
// ---------------------------------------------------------------------

pub struct FaultsReport {
    pub device: DeviceStorm,
    pub breaker: BreakerStorm,
    pub shed: ShedStorm,
    pub tcp: TcpFaultStorm,
}

pub fn collect(quick: bool) -> FaultsReport {
    FaultsReport {
        device: device_storm(quick),
        breaker: breaker_storm(quick),
        shed: shed_storm(quick),
        tcp: tcp_storm(quick),
    }
}

/// Machine-readable form (`BENCH_faults.json`).
pub fn report_json(r: &FaultsReport) -> Json {
    let device_rows = r
        .device
        .rows
        .iter()
        .map(|row| {
            Json::Obj(vec![
                ("fault".into(), Json::str("device")),
                ("phase".into(), Json::str(row.phase)),
                ("completed".into(), Json::Int(row.completed as i64)),
                ("p50_ms".into(), Json::Num(row.p50_ms)),
                ("p99_ms".into(), Json::Num(row.p99_ms)),
            ])
        })
        .collect();
    let breaker_rows = vec![
        Json::Obj(vec![
            ("breaker".into(), Json::str("baseline")),
            ("jain_healthy".into(), Json::Num(r.breaker.jain_baseline)),
        ]),
        Json::Obj(vec![
            ("breaker".into(), Json::str("poison")),
            ("jain_healthy".into(), Json::Num(r.breaker.jain_poison)),
            ("quarantined".into(), Json::Int(r.breaker.quarantined as i64)),
            ("exec_failed".into(), Json::Int(r.breaker.exec_failed as i64)),
            (
                "breaker_trips".into(),
                Json::Int(r.breaker.stats.breaker_trips as i64),
            ),
            (
                "breaker_probes".into(),
                Json::Int(r.breaker.stats.breaker_probes as i64),
            ),
        ]),
    ];
    let shed_rows = r
        .shed
        .rows
        .iter()
        .map(|row| {
            Json::Obj(vec![
                ("shed".into(), Json::str(row.shed)),
                ("arrivals".into(), Json::Int(row.arrivals as i64)),
                ("admitted".into(), Json::Int(row.admitted as i64)),
                ("rejected".into(), Json::Int(row.rejected as i64)),
                ("measured".into(), Json::Int(row.measured as i64)),
                ("p50_ms".into(), Json::Num(row.p50_ms)),
                ("p99_ms".into(), Json::Num(row.p99_ms)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::str("mqfq-bench-faults/v1")),
        ("device_phases".into(), Json::Arr(device_rows)),
        (
            "device_recovery_ratio".into(),
            Json::Num(r.device.recovery_ratio),
        ),
        ("device_conserved".into(), Json::Bool(r.device.conserved)),
        (
            "device_exec_failed".into(),
            Json::Int(r.device.exec_failed as i64),
        ),
        (
            "device_faults_injected".into(),
            Json::Int((r.device.stats.faults_device + r.device.stats.faults_transient) as i64),
        ),
        ("breaker_rows".into(), Json::Arr(breaker_rows)),
        ("breaker_jain_ratio".into(), Json::Num(r.breaker.jain_ratio)),
        ("breaker_conserved".into(), Json::Bool(r.breaker.conserved)),
        ("shed_rows".into(), Json::Arr(shed_rows)),
        ("shed_deadline_s".into(), Json::Num(r.shed.deadline_s)),
        ("shed_p99_ratio".into(), Json::Num(r.shed.p99_ratio)),
        (
            "shed_unprotected_ratio".into(),
            Json::Num(r.shed.unprotected_ratio),
        ),
        ("shed_conserved".into(), Json::Bool(r.shed.conserved)),
        ("tcp_pre_p99_ms".into(), Json::Num(r.tcp.pre_p99_ms)),
        ("tcp_post_p99_ms".into(), Json::Num(r.tcp.post_p99_ms)),
        ("tcp_recovery_ratio".into(), Json::Num(r.tcp.recovery_ratio)),
        (
            "tcp_fates".into(),
            Json::Obj(vec![
                ("done".into(), Json::Int(r.tcp.done as i64)),
                ("exec_failed".into(), Json::Int(r.tcp.exec_failed as i64)),
                ("other".into(), Json::Int(r.tcp.other as i64)),
            ]),
        ),
        ("tcp_max_wait_ms".into(), Json::Num(r.tcp.max_wait_ms)),
        (
            "tcp_faults_device".into(),
            Json::Int(r.tcp.faults_device as i64),
        ),
        (
            "tcp_faults_transient".into(),
            Json::Int(r.tcp.faults_transient as i64),
        ),
        ("tcp_retries".into(), Json::Int(r.tcp.retries as i64)),
        ("tcp_conserved".into(), Json::Bool(r.tcp.conserved)),
        ("tcp_accepted".into(), Json::Int(r.tcp.accepted as i64)),
        ("tcp_completed".into(), Json::Int(r.tcp.completed as i64)),
        ("tcp_failed".into(), Json::Int(r.tcp.failed as i64)),
    ])
}

pub fn main() {
    let quick = std::env::var("FAULTS_QUICK").is_ok();
    println!(
        "== §Faults: device fault tolerance (inject/retry/breaker/shed){} ==",
        if quick { " (quick)" } else { "" }
    );
    let report = collect(quick);

    let d = &report.device;
    println!("{:<10} {:>10} {:>10} {:>10}", "phase", "completed", "p50 ms", "p99 ms");
    for row in &d.rows {
        println!(
            "{:<10} {:>10} {:>10.2} {:>10.2}",
            row.phase, row.completed, row.p50_ms, row.p99_ms
        );
    }
    println!(
        "device: {} arrivals = {} completed + {} exec-failed (conserved: {}); \
         {} device + {} transient faults, {} retries; recovery {:.2}x",
        d.arrivals,
        d.completed,
        d.exec_failed,
        d.conserved,
        d.stats.faults_device,
        d.stats.faults_transient,
        d.stats.retries,
        d.recovery_ratio
    );
    let b = &report.breaker;
    println!(
        "breaker: {} trips, {} probes, {} quarantined, {} exec-failed; \
         healthy Jain {:.4} vs baseline {:.4} ({:.3}x)",
        b.stats.breaker_trips,
        b.stats.breaker_probes,
        b.quarantined,
        b.exec_failed,
        b.jain_poison,
        b.jain_baseline,
        b.jain_ratio
    );
    let s = &report.shed;
    for row in &s.rows {
        println!(
            "shed[{:<11}] arrivals={:<6} admitted={:<6} rejected={:<5} p99={:.2} ms",
            row.shed, row.arrivals, row.admitted, row.rejected, row.p99_ms
        );
    }
    println!(
        "shed: deadline {:.3}s; admitted p99 {:.2}x uncontended (unprotected {:.2}x)",
        s.deadline_s, s.p99_ratio, s.unprotected_ratio
    );
    let t = &report.tcp;
    println!(
        "tcp: burst {} -> done={} exec-failed={} other={} (max wait {:.1} ms); \
         {} device + {} transient faults, {} retries; recovery {:.2}x (conserved: {})",
        t.burst,
        t.done,
        t.exec_failed,
        t.other,
        t.max_wait_ms,
        t.faults_device,
        t.faults_transient,
        t.retries,
        t.recovery_ratio,
        t.conserved
    );
    match json::write_file("BENCH_faults.json", &report_json(&report)) {
        Ok(()) => println!("wrote BENCH_faults.json"),
        Err(e) => println!("BENCH_faults.json not written: {e}"),
    }

    // Correctness invariants hold in every mode — they are the point of
    // the harness, not perf gates. Exactly-once / conservation first.
    assert!(report.device.conserved, "device storm lost invocations");
    assert!(report.device.fleet_healed, "scheduled recovery never landed");
    assert!(report.device.stats.faults_device >= 1, "device failure stranded nothing");
    assert!(report.breaker.conserved, "breaker storm lost invocations");
    assert!(report.breaker.stats.breaker_trips >= 1, "poison tenant never tripped the breaker");
    assert!(report.breaker.quarantined > 0, "open breaker never quarantined an arrival");
    assert!(report.breaker.stats.breaker_probes >= 1, "cooldown never produced a half-open probe");
    assert!(report.shed.conserved, "shed storm lost invocations");
    assert!(report.shed.shed_count > 0, "2x overload never shed");
    assert!(report.tcp.conserved, "tcp ticket fates do not conserve");
    assert_eq!(report.tcp.other, 0, "a tcp ticket resolved to an unexpected fate");
    // Timing gates only where timing is meaningful (release, full run).
    if !cfg!(debug_assertions) && !quick {
        assert!(
            report.device.recovery_ratio <= RECOVERY_GATE,
            "sim post-recovery p99 {:.2}x pre-fault (gate {RECOVERY_GATE}x)",
            report.device.recovery_ratio
        );
        assert!(
            report.breaker.jain_ratio >= JAIN_GATE,
            "healthy-tenant Jain {:.3}x of no-poison (gate {JAIN_GATE}x)",
            report.breaker.jain_ratio
        );
        assert!(
            report.shed.p99_ratio <= SHED_GATE,
            "admitted p99 {:.2}x uncontended at 2x load (gate {SHED_GATE}x)",
            report.shed.p99_ratio
        );
        assert!(
            report.tcp.faults_device >= 1,
            "tcp storm: the device failure stranded no in-flight work"
        );
        assert!(report.tcp.retries >= 1, "tcp storm: no transient fault was retried");
        assert!(
            report.tcp.max_wait_ms < STORM_DEADLINE_MS as f64,
            "a tcp wait consumed its whole deadline window"
        );
        assert!(
            report.tcp.recovery_ratio <= RECOVERY_GATE,
            "tcp post-recovery p99 {:.2}x pre-fault (gate {RECOVERY_GATE}x)",
            report.tcp.recovery_ratio
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_storm_conserves_and_heals() {
        let s = device_storm(true);
        assert_eq!(s.rows.len(), 5);
        assert!(
            s.conserved,
            "{} arrivals != {} completed + {} failed",
            s.arrivals, s.completed, s.exec_failed
        );
        assert!(s.fleet_healed);
        assert!(s.stats.faults_device >= 1, "{:?}", s.stats);
        assert!(s.stats.faults_transient >= 1, "{:?}", s.stats);
        assert!(s.stats.retries >= 1, "{:?}", s.stats);
        // The failure window visibly hurt relative to the pre window.
        let pre = s.rows.iter().find(|r| r.phase == "pre").unwrap();
        assert!(pre.completed > 0);
    }

    #[test]
    fn breaker_storm_quarantines_and_reprobes() {
        let s = breaker_storm(true);
        assert!(s.conserved);
        assert!(s.stats.breaker_trips >= 1, "{:?}", s.stats);
        assert!(s.quarantined > 0);
        assert!(s.stats.breaker_probes >= 1, "{:?}", s.stats);
        assert!(s.exec_failed > 0, "poison attempts must exhaust budgets");
        assert!(s.jain_baseline > 0.0 && s.jain_poison > 0.0);
    }

    #[test]
    fn shed_storm_sheds_under_overload_only() {
        let s = shed_storm(true);
        assert_eq!(s.rows.len(), 3);
        assert!(s.conserved);
        assert!(s.shed_count > 0, "2x load never shed");
        let unc = &s.rows[0];
        assert_eq!(unc.rejected, 0, "uncontended run must not reject");
        // Shedding keeps the admitted tail below the unprotected run.
        assert!(
            s.p99_ratio < s.unprotected_ratio,
            "shed {:.2}x !< unprotected {:.2}x",
            s.p99_ratio,
            s.unprotected_ratio
        );
    }

    #[test]
    fn report_json_has_identity_and_gate_keys() {
        let r = FaultsReport {
            device: DeviceStorm {
                rows: vec![DevicePhaseRow {
                    phase: "pre",
                    completed: 10,
                    p50_ms: 1.0,
                    p99_ms: 2.0,
                }],
                arrivals: 10,
                completed: 10,
                exec_failed: 0,
                conserved: true,
                stats: FaultStats::default(),
                fleet_healed: true,
                recovery_ratio: 1.1,
            },
            breaker: BreakerStorm {
                arrivals: 100,
                completed: 90,
                exec_failed: 4,
                quarantined: 6,
                stats: FaultStats::default(),
                jain_baseline: 0.99,
                jain_poison: 0.98,
                jain_ratio: 0.99,
                conserved: true,
            },
            shed: ShedStorm {
                rows: vec![ShedRow {
                    shed: "uncontended",
                    arrivals: 100,
                    admitted: 100,
                    rejected: 0,
                    measured: 80,
                    p50_ms: 1.0,
                    p99_ms: 2.0,
                }],
                deadline_s: 0.05,
                p99_ratio: 1.4,
                unprotected_ratio: 9.0,
                shed_count: 12,
                conserved: true,
            },
            tcp: TcpFaultStorm {
                pre_p99_ms: 1.5,
                post_p99_ms: 1.8,
                recovery_ratio: 1.2,
                burst: 16,
                done: 16,
                exec_failed: 0,
                other: 0,
                max_wait_ms: 120.0,
                faults_device: 3,
                faults_transient: 7,
                retries: 10,
                conserved: true,
                accepted: 216,
                completed: 216,
                failed: 0,
            },
        };
        let doc = report_json(&r).render();
        for key in [
            "\"schema\"",
            "\"device_phases\"",
            "\"fault\"",
            "\"phase\"",
            "\"breaker_rows\"",
            "\"breaker\"",
            "\"breaker_jain_ratio\"",
            "\"shed_rows\"",
            "\"shed\"",
            "\"shed_p99_ratio\"",
            "\"tcp_recovery_ratio\"",
            "\"tcp_fates\"",
            "\"tcp_faults_device\"",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
        assert!(doc.contains("mqfq-bench-faults/v1"));
    }

    #[test]
    fn prom_sum_folds_labeled_series_and_skips_comments() {
        let body = "# TYPE mqfq_retries_total counter\n\
                    mqfq_retries_total{shard=\"0\"} 3\n\
                    mqfq_retries_total{shard=\"1\"} 4\n\
                    mqfq_retry_exhausted_total{shard=\"0\"} 9\n";
        assert_eq!(prom_sum(body, "mqfq_retries_total"), 7);
        assert_eq!(prom_sum(body, "mqfq_retry_exhausted_total"), 9);
        assert_eq!(prom_sum(body, "mqfq_faults_device_total"), 0);
    }
}
