//! §6.4 ablations: preferential (sticky) dispatch on/off, and the EEVDF
//! CPU-scheduling baseline comparison.

use crate::plane::PlaneConfig;
use crate::scheduler::policies::PolicyKind;
use crate::scheduler::MqfqConfig;
use crate::workload::azure::{self, AzureConfig};

use super::{run, summary_table, write_summary_csv, RunSummary};

pub fn rows() -> Vec<RunSummary> {
    let workload = || {
        azure::generate(&AzureConfig {
            trace_id: 4,
            duration_s: 600.0,
            load_scale: 1.0,
        })
    };
    let mut out = Vec::new();
    for (label, sticky) in [("mqfq-sticky", true), ("mqfq-no-sticky", false)] {
        let (w, t) = workload();
        let cfg = PlaneConfig {
            policy: PolicyKind::Mqfq,
            d: 2,
            mqfq: MqfqConfig {
                sticky,
                ..Default::default()
            },
            ..Default::default()
        };
        out.push(run(label, w, &t, cfg).0);
    }
    let (w, t) = workload();
    out.push(
        run(
            "eevdf",
            w,
            &t,
            PlaneConfig {
                policy: PolicyKind::Eevdf,
                d: 2,
                ..Default::default()
            },
        )
        .0,
    );
    let (w, t) = workload();
    out.push(
        run(
            "sfq (T=0)",
            w,
            &t,
            PlaneConfig {
                policy: PolicyKind::Sfq,
                d: 2,
                ..Default::default()
            },
        )
        .0,
    );
    out
}

pub fn main() {
    println!("== §6.4 ablations: sticky dispatch, EEVDF, classic SFQ ==");
    let rows = rows();
    print!("{}", summary_table(&rows).render());
    write_summary_csv("ablation", &rows).unwrap();
    println!(
        "(paper: no-sticky +1–30% latency; MQFQ-Sticky beats EEVDF by ~40%)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sticky_and_overrun_help() {
        let rows = rows();
        let get = |l: &str| {
            rows.iter()
                .find(|r| r.label == l)
                .unwrap()
                .wavg_latency_s
        };
        let sticky = get("mqfq-sticky");
        // Sticky should not be worse than non-sticky beyond noise.
        assert!(
            sticky <= get("mqfq-no-sticky") * 1.10,
            "sticky {:.2} vs non {:.2}",
            sticky,
            get("mqfq-no-sticky")
        );
        // Full MQFQ-Sticky should beat EEVDF and classic SFQ.
        assert!(sticky < get("eevdf"), "vs eevdf {:.2}", get("eevdf"));
        assert!(sticky < get("sfq (T=0)"), "vs sfq {:.2}", get("sfq (T=0)"));
    }
}
