//! §Fig 10 (beyond the paper): heterogeneous-fleet sweep — what does
//! capacity-aware routing buy when shards stop being identical?
//!
//! Sweeps fleet shapes (uniform 4×V100, mixed V100/A30, V100 beside
//! MIG-sliced A30s, and 2×/4× capacity-skewed V100 clusters) × router
//! on the Zipf-1.5 trace, with offered load proportional to total
//! fleet capacity (constant per-V100-equivalent rate, so every fleet
//! sees the same relative pressure). Reports p50/p99 latency, Jain
//! fairness, cold-start ratio, and utilization imbalance per device
//! class and per shard. Results land in
//! `results/fig10_heterogeneous.csv` and machine-readable
//! `BENCH_hetero.json` (`scripts/bench_diff.sh`, identity-keyed by
//! fleet + router).
//!
//! The gate ([`assert_capacity_win`]): on fleets with ≥ 2× capacity
//! skew, the capacity-weighted [`StickyCh`] must not lose to the
//! capacity-blind ablation on p99 — the weighted ring homes
//! proportionally more functions on fat shards and sheds load off thin
//! ones sooner, which is the whole point of threading `DeviceSpec`
//! capacities up to the front end.
//!
//! [`StickyCh`]: crate::cluster::router::StickyCh

use std::collections::BTreeMap;

use crate::cluster::{ClusterConfig, RouterKind};
use crate::gpu::{uniform_fleet, DeviceSpec, MultiplexMode, A30, V100};
use crate::metrics::jain_index;
use crate::plane::PlaneConfig;
use crate::sim::{replay_cluster, ClusterReplayResult};
use crate::util::csv::CsvWriter;
use crate::util::json::{self, Json};
use crate::util::stats::percentiles;
use crate::util::table::Table;
use crate::workload::zipf::{self, ZipfConfig};

/// One swept cluster shape: a name plus each shard's fleet.
#[derive(Debug, Clone)]
pub struct Fleet {
    pub name: &'static str,
    pub shard_fleets: Vec<Vec<DeviceSpec>>,
}

impl Fleet {
    pub fn n_shards(&self) -> usize {
        self.shard_fleets.len()
    }

    /// Per-shard capacities (V100-equivalents).
    pub fn capacities(&self) -> Vec<f64> {
        self.shard_fleets
            .iter()
            .map(|f| f.iter().map(|s| s.capacity()).sum())
            .collect()
    }

    pub fn total_capacity(&self) -> f64 {
        self.capacities().iter().sum()
    }

    /// Max/min shard-capacity ratio (1.0 = uniform).
    pub fn capacity_skew(&self) -> f64 {
        let caps = self.capacities();
        let max = caps.iter().cloned().fold(f64::MIN, f64::max);
        let min = caps.iter().cloned().fold(f64::MAX, f64::min);
        max / min
    }
}

/// The standard fig10 fleet shapes (4 shards each).
pub fn standard_fleets() -> Vec<Fleet> {
    let v100 = |n| uniform_fleet(n, V100, MultiplexMode::Plain);
    let a30 = uniform_fleet(1, A30, MultiplexMode::Plain);
    let a30_mig = uniform_fleet(1, A30, MultiplexMode::Mig(2));
    vec![
        Fleet {
            name: "uniform-4xv100",
            shard_fleets: vec![v100(1), v100(1), v100(1), v100(1)],
        },
        Fleet {
            name: "mixed-v100-a30",
            shard_fleets: vec![v100(1), v100(1), a30.clone(), a30],
        },
        Fleet {
            name: "mig-mixed",
            shard_fleets: vec![v100(1), v100(1), a30_mig.clone(), a30_mig],
        },
        Fleet {
            name: "skew2x",
            shard_fleets: vec![v100(2), v100(2), v100(1), v100(1)],
        },
        Fleet {
            name: "skew4x",
            shard_fleets: vec![v100(4), v100(1), v100(1), v100(1)],
        },
    ]
}

/// Sweep parameters (the bench uses the defaults; tests shrink them).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub fleets: Vec<Fleet>,
    pub routers: Vec<RouterKind>,
    /// Offered load per V100-equivalent of fleet capacity, req/s (the
    /// total rate scales with each fleet's capacity).
    pub per_capacity_rate: f64,
    pub duration_s: f64,
    pub n_funcs: usize,
    pub seed: u64,
    /// StickyCh bounded-load spill factor.
    pub load_factor: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            fleets: standard_fleets(),
            routers: vec![
                RouterKind::RoundRobin,
                RouterKind::LeastLoaded,
                RouterKind::StickyChBlind,
                RouterKind::StickyCh,
            ],
            per_capacity_rate: 2.0,
            duration_s: 600.0,
            n_funcs: 24,
            seed: 42,
            load_factor: 1.25,
        }
    }
}

/// One (fleet, router) cell of the sweep.
#[derive(Debug, Clone)]
pub struct HeteroRow {
    pub fleet: &'static str,
    pub router: &'static str,
    pub capacity_skew: f64,
    pub total_capacity: f64,
    pub invocations: usize,
    pub p50_s: f64,
    pub p99_s: f64,
    pub wavg_s: f64,
    pub cold_ratio: f64,
    /// Jain index over per-function mean latencies (1.0 = perfectly fair).
    pub fairness_jain: f64,
    pub mean_util: f64,
    /// Max − min mean utilization across device *classes* (v100,
    /// a30/mig2, ...); 0 when the fleet has one class.
    pub class_util_spread: f64,
    /// Max − min mean utilization across *shards* — the imbalance
    /// capacity-blind routing leaves on skewed fleets.
    pub shard_util_spread: f64,
    pub makespan_s: f64,
    /// Max per-shard arrival share vs an even split (1.0 = balanced;
    /// note on skewed fleets an even split is *not* the goal).
    pub routing_imbalance: f64,
    /// StickyCh arrivals routed off their home shard (0 for others).
    pub spills: u64,
}

/// Measure one replay into a sweep row (needs `&mut` for the exact
/// per-device utilization integrals).
pub fn measure(fleet: &Fleet, router: RouterKind, r: &mut ClusterReplayResult) -> HeteroRow {
    let rec = r.recorder();
    let lat = rec.latencies_s();
    let pcts = percentiles(&lat, &[50.0, 99.0]);
    let per_fn: Vec<f64> = rec.per_function().iter().map(|a| a.mean_latency_s).collect();
    let row_basics = (
        rec.len(),
        pcts[0],
        pcts[1],
        rec.weighted_avg_latency_s(),
        r.cluster.pool_stats().cold_ratio(),
        jain_index(&per_fn),
        crate::types::to_secs(r.makespan),
        r.cluster.routing_imbalance(),
        r.cluster.spills(),
    );
    // Per-class and per-shard utilization imbalance from the exact
    // integrals at the makespan.
    let at = r.makespan.max(1);
    let mut class_sum: BTreeMap<String, (f64, usize)> = BTreeMap::new();
    let mut shard_means = Vec::new();
    for shard in &mut r.cluster.shards {
        let rows = shard.device_utilizations(at);
        let mean = rows.iter().map(|(_, u)| u).sum::<f64>() / rows.len().max(1) as f64;
        shard_means.push(mean);
        for (label, u) in rows {
            let e = class_sum.entry(label).or_insert((0.0, 0));
            e.0 += u;
            e.1 += 1;
        }
    }
    let spread = |means: &[f64]| -> f64 {
        if means.len() <= 1 {
            return 0.0;
        }
        let max = means.iter().cloned().fold(f64::MIN, f64::max);
        let min = means.iter().cloned().fold(f64::MAX, f64::min);
        max - min
    };
    let class_means: Vec<f64> = class_sum.values().map(|(s, n)| s / *n as f64).collect();
    let (invocations, p50_s, p99_s, wavg_s, cold_ratio, fairness_jain, makespan_s, imbal, spills) =
        row_basics;
    HeteroRow {
        fleet: fleet.name,
        router: router.name(),
        capacity_skew: fleet.capacity_skew(),
        total_capacity: fleet.total_capacity(),
        invocations,
        p50_s,
        p99_s,
        wavg_s,
        cold_ratio,
        fairness_jain,
        mean_util: r.mean_util,
        class_util_spread: spread(&class_means),
        shard_util_spread: spread(&shard_means),
        makespan_s,
        routing_imbalance: imbal,
        spills,
    }
}

/// Run the full sweep: every (fleet, router) cell replays the same
/// capacity-scaled Zipf-1.5 trace. Deterministic for a fixed
/// [`SweepConfig`].
pub fn sweep(cfg: &SweepConfig) -> Vec<HeteroRow> {
    let mut rows = Vec::new();
    for fleet in &cfg.fleets {
        let (w, t) = zipf::generate(&ZipfConfig {
            n_funcs: cfg.n_funcs,
            total_rate: cfg.per_capacity_rate * fleet.total_capacity(),
            duration_s: cfg.duration_s,
            seed: cfg.seed,
            ..Default::default()
        });
        let shard_planes: Vec<PlaneConfig> = fleet
            .shard_fleets
            .iter()
            .map(|devs| PlaneConfig {
                devices: devs.clone(),
                ..Default::default()
            })
            .collect();
        for &router in &cfg.routers {
            let ccfg = ClusterConfig {
                n_shards: fleet.n_shards(),
                router,
                plane: PlaneConfig::default(),
                shard_planes: shard_planes.clone(),
                load_factor: cfg.load_factor,
                seed: cfg.seed,
                ..Default::default()
            };
            let mut r = replay_cluster(w.clone(), &t, ccfg);
            rows.push(measure(fleet, router, &mut r));
        }
    }
    rows
}

/// Machine-readable form of the sweep (`BENCH_hetero.json`).
pub fn report_json(cfg: &SweepConfig, rows: &[HeteroRow]) -> Json {
    let row_json = |r: &HeteroRow| {
        Json::Obj(vec![
            ("fleet".into(), Json::str(r.fleet)),
            ("router".into(), Json::str(r.router)),
            ("capacity_skew".into(), Json::Num(r.capacity_skew)),
            ("total_capacity".into(), Json::Num(r.total_capacity)),
            ("invocations".into(), Json::Int(r.invocations as i64)),
            ("p50_s".into(), Json::Num(r.p50_s)),
            ("p99_s".into(), Json::Num(r.p99_s)),
            ("wavg_s".into(), Json::Num(r.wavg_s)),
            ("cold_ratio".into(), Json::Num(r.cold_ratio)),
            ("fairness_jain".into(), Json::Num(r.fairness_jain)),
            ("mean_util".into(), Json::Num(r.mean_util)),
            ("class_util_spread".into(), Json::Num(r.class_util_spread)),
            ("shard_util_spread".into(), Json::Num(r.shard_util_spread)),
            ("makespan_s".into(), Json::Num(r.makespan_s)),
            ("routing_imbalance".into(), Json::Num(r.routing_imbalance)),
            ("spills".into(), Json::Int(r.spills as i64)),
        ])
    };
    Json::Obj(vec![
        ("schema".into(), Json::str("mqfq-bench-hetero/v1")),
        (
            "config".into(),
            Json::Obj(vec![
                (
                    "per_capacity_rate".into(),
                    Json::Num(cfg.per_capacity_rate),
                ),
                ("duration_s".into(), Json::Num(cfg.duration_s)),
                ("n_funcs".into(), Json::Int(cfg.n_funcs as i64)),
                ("seed".into(), Json::Int(cfg.seed as i64)),
                ("load_factor".into(), Json::Num(cfg.load_factor)),
                ("trace".into(), Json::str("zipf-1.5")),
            ]),
        ),
        ("rows".into(), Json::Arr(rows.iter().map(row_json).collect())),
    ])
}

/// Render the standard comparison table.
pub fn rows_table(rows: &[HeteroRow]) -> Table {
    let mut t = Table::new(&[
        "fleet",
        "router",
        "skew",
        "invocations",
        "p50(s)",
        "p99(s)",
        "avg(s)",
        "cold%",
        "jain",
        "util%",
        "Δclass",
        "Δshard",
        "spills",
    ]);
    for r in rows {
        t.row(&[
            r.fleet.to_string(),
            r.router.to_string(),
            format!("{:.1}", r.capacity_skew),
            r.invocations.to_string(),
            format!("{:.3}", r.p50_s),
            format!("{:.3}", r.p99_s),
            format!("{:.3}", r.wavg_s),
            format!("{:.2}", r.cold_ratio * 100.0),
            format!("{:.3}", r.fairness_jain),
            format!("{:.1}", r.mean_util * 100.0),
            format!("{:.3}", r.class_util_spread),
            format!("{:.3}", r.shard_util_spread),
            r.spills.to_string(),
        ]);
    }
    t
}

fn write_csv(rows: &[HeteroRow]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        "results/fig10_heterogeneous.csv",
        &[
            "fleet",
            "router",
            "capacity_skew",
            "total_capacity",
            "invocations",
            "p50_s",
            "p99_s",
            "wavg_s",
            "cold_ratio",
            "fairness_jain",
            "mean_util",
            "class_util_spread",
            "shard_util_spread",
            "makespan_s",
            "routing_imbalance",
            "spills",
        ],
    )?;
    for r in rows {
        w.rowv(&[
            r.fleet.to_string(),
            r.router.to_string(),
            format!("{:.4}", r.capacity_skew),
            format!("{:.4}", r.total_capacity),
            r.invocations.to_string(),
            format!("{:.6}", r.p50_s),
            format!("{:.6}", r.p99_s),
            format!("{:.6}", r.wavg_s),
            format!("{:.6}", r.cold_ratio),
            format!("{:.6}", r.fairness_jain),
            format!("{:.6}", r.mean_util),
            format!("{:.6}", r.class_util_spread),
            format!("{:.6}", r.shard_util_spread),
            format!("{:.3}", r.makespan_s),
            format!("{:.4}", r.routing_imbalance),
            r.spills.to_string(),
        ])?;
    }
    w.flush()
}

/// The capacity win the refactor exists to demonstrate: on every swept
/// fleet with ≥ 2× capacity skew, capacity-weighted StickyCh must not
/// lose to the capacity-blind ablation on p99 latency. Behavioral (not
/// timing), so it gates debug and release runs alike. (If a future
/// calibration change trips this on real numbers, tune per the ROADMAP
/// protocol and record it in CHANGES.md.)
pub fn assert_capacity_win(rows: &[HeteroRow]) {
    let cell = |fleet: &str, router: &str| {
        rows.iter()
            .find(|r| r.fleet == fleet && r.router == router)
    };
    let mut checked = 0;
    let fleets: Vec<&'static str> = {
        let mut f: Vec<&'static str> = rows
            .iter()
            .filter(|r| r.capacity_skew >= 2.0)
            .map(|r| r.fleet)
            .collect();
        f.dedup();
        f
    };
    for fleet in fleets {
        let (Some(weighted), Some(blind)) = (
            cell(fleet, RouterKind::StickyCh.name()),
            cell(fleet, RouterKind::StickyChBlind.name()),
        ) else {
            continue; // sweep didn't include both sticky variants
        };
        assert!(
            weighted.p99_s <= blind.p99_s + 1e-9,
            "{fleet}: capacity-weighted StickyCh p99 {:.4}s loses to blind {:.4}s",
            weighted.p99_s,
            blind.p99_s
        );
        checked += 1;
    }
    assert!(
        checked > 0,
        "capacity gate never exercised: no skewed fleet with both sticky variants"
    );
}

/// Run the sweep with `cfg`, print, persist, and gate.
pub fn run(cfg: &SweepConfig) {
    println!("== Fig 10: heterogeneous fleets (fleet × router, zipf-1.5, capacity-scaled) ==");
    let t0 = std::time::Instant::now();
    let rows = sweep(cfg);
    print!("{}", rows_table(&rows).render());
    println!("[swept {} cells in {:.2?}]", rows.len(), t0.elapsed());
    match write_csv(&rows) {
        Ok(()) => println!("wrote results/fig10_heterogeneous.csv"),
        Err(e) => println!("csv not written: {e}"),
    }
    match json::write_file("BENCH_hetero.json", &report_json(cfg, &rows)) {
        Ok(()) => println!("wrote BENCH_hetero.json"),
        Err(e) => println!("BENCH_hetero.json not written: {e}"),
    }
    assert_capacity_win(&rows);
    println!("capacity gate: weighted StickyCh holds p99 against the blind ring at ≥2× skew");
}

pub fn main() {
    run(&SweepConfig::default());
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small sweep the debug-mode tests can afford: the most skewed
    /// fleet (strongest capacity signal) plus the uniform control.
    fn small_cfg() -> SweepConfig {
        let fleets = standard_fleets();
        SweepConfig {
            fleets: fleets
                .into_iter()
                .filter(|f| f.name == "uniform-4xv100" || f.name == "skew4x")
                .collect(),
            routers: vec![
                RouterKind::RoundRobin,
                RouterKind::StickyChBlind,
                RouterKind::StickyCh,
            ],
            duration_s: 120.0,
            ..Default::default()
        }
    }

    #[test]
    fn standard_fleets_cover_the_shapes() {
        let fleets = standard_fleets();
        assert_eq!(fleets.len(), 5);
        let get = |n: &str| fleets.iter().find(|f| f.name == n).unwrap();
        assert!((get("uniform-4xv100").capacity_skew() - 1.0).abs() < 1e-12);
        assert!((get("skew2x").capacity_skew() - 2.0).abs() < 1e-12);
        assert!((get("skew4x").capacity_skew() - 4.0).abs() < 1e-12);
        assert!(get("mixed-v100-a30").capacity_skew() > 1.0);
        // The MIG fleet expands to two vGPUs on its A30 shards.
        let mig = get("mig-mixed");
        assert_eq!(mig.shard_fleets[2][0].n_vgpus(), 2);
        assert!((mig.total_capacity() - (2.0 + 2.0 / 0.92)).abs() < 1e-9);
    }

    #[test]
    fn weighted_sticky_holds_p99_at_4x_skew() {
        let rows = sweep(&small_cfg());
        assert_capacity_win(&rows);
        for r in &rows {
            assert!(r.invocations > 0, "{} @ {} empty", r.fleet, r.router);
            assert!(r.p99_s >= r.p50_s);
            assert!(r.fairness_jain > 0.0 && r.fairness_jain <= 1.0 + 1e-12);
        }
        // On the uniform fleet the two sticky variants are the same
        // router by construction: identical cells.
        let cell = |router: &str| {
            rows.iter()
                .find(|r| r.fleet == "uniform-4xv100" && r.router == router)
                .unwrap()
        };
        let (w, b) = (cell("sticky-ch"), cell("sticky-blind"));
        assert_eq!(w.invocations, b.invocations);
        assert!((w.p99_s - b.p99_s).abs() < 1e-12);
        assert_eq!(w.spills, b.spills);
    }

    #[test]
    fn sweep_is_deterministic() {
        let cfg = SweepConfig {
            fleets: standard_fleets()
                .into_iter()
                .filter(|f| f.name == "skew2x")
                .collect(),
            routers: vec![RouterKind::StickyCh],
            duration_s: 60.0,
            ..Default::default()
        };
        let a = report_json(&cfg, &sweep(&cfg)).render();
        let b = report_json(&cfg, &sweep(&cfg)).render();
        assert_eq!(a, b, "same seed must produce identical BENCH rows");
    }

    #[test]
    fn report_json_has_the_tracked_fields() {
        let cfg = SweepConfig {
            fleets: standard_fleets()
                .into_iter()
                .filter(|f| f.name == "mixed-v100-a30")
                .collect(),
            routers: vec![RouterKind::LeastLoaded],
            duration_s: 30.0,
            ..Default::default()
        };
        let rows = sweep(&cfg);
        assert_eq!(rows.len(), 1);
        // Two device classes on this fleet: the spread is meaningful.
        let doc = report_json(&cfg, &rows).render();
        for key in [
            "\"schema\"",
            "mqfq-bench-hetero/v1",
            "\"fleet\"",
            "\"router\"",
            "\"capacity_skew\"",
            "\"p99_s\"",
            "\"cold_ratio\"",
            "\"class_util_spread\"",
            "\"shard_util_spread\"",
            "\"spills\"",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
    }
}
