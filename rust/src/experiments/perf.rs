//! §Perf: hot-path microbenchmarks — dispatch decision latency, sim
//! engine throughput, PJRT execution round-trip (when artifacts exist).
//! Results feed EXPERIMENTS.md §Perf.

use crate::plane::PlaneConfig;
use crate::scheduler::{Invocation, MqfqConfig, MqfqSticky, Policy, PolicyCtx};
use crate::types::{FuncId, InvocationId, SEC};
use crate::util::bench::{bench, black_box, BenchResult};
use crate::workload::zipf::{self, ZipfConfig};

/// Dispatch-decision latency at a given flow count: one enqueue + one
/// dispatch per iteration over a steady backlog.
pub fn bench_dispatch(n_flows: usize, budget_ms: u64) -> BenchResult {
    let mut p = MqfqSticky::new(n_flows, MqfqConfig::default());
    let in_flight = vec![0usize; n_flows];
    // Pre-fill every flow.
    let mut id = 0u64;
    for f in 0..n_flows {
        for _ in 0..4 {
            p.enqueue(
                Invocation {
                    id: InvocationId(id),
                    func: FuncId(f as u32),
                    arrived: 0,
                },
                0,
            );
            id += 1;
        }
    }
    let mut now = SEC;
    let mut rr = 0u32;
    bench(&format!("mqfq dispatch ({n_flows} flows)"), budget_ms, || {
        now += 1000;
        // Keep the backlog steady: re-enqueue one item round-robin.
        p.enqueue(
            Invocation {
                id: InvocationId(id),
                func: FuncId(rr % n_flows as u32),
                arrived: now,
            },
            now,
        );
        id += 1;
        rr += 1;
        let ctx = PolicyCtx {
            in_flight: &in_flight,
            d: 2,
        };
        let inv = p.dispatch(now, &ctx);
        if let Some(inv) = &inv {
            p.on_complete(inv.func, SEC, now);
        }
        black_box(inv);
    })
}

/// Sim-engine throughput in events/second on a standard Zipf replay.
pub fn sim_events_per_sec() -> (f64, u64) {
    let (w, t) = zipf::generate(&ZipfConfig {
        n_funcs: 24,
        total_rate: 4.0,
        duration_s: 600.0,
        seed: 3,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let r = crate::sim::replay(w, &t, PlaneConfig::default());
    let wall = t0.elapsed().as_secs_f64();
    (r.events as f64 / wall, r.events)
}

/// PJRT execution round-trip per catalog artifact (None if artifacts
/// have not been built).
pub fn pjrt_roundtrips() -> Option<Vec<(String, f64)>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        return None;
    }
    let mut rt = crate::runtime::PjrtRuntime::new(&dir).ok()?;
    let names = rt.load_all().ok()?;
    let mut out = Vec::new();
    for name in names {
        rt.execute(&name).ok()?; // warm
        let t0 = std::time::Instant::now();
        let iters = 5;
        for _ in 0..iters {
            rt.execute(&name).ok()?;
        }
        out.push((name, t0.elapsed().as_secs_f64() / iters as f64));
    }
    Some(out)
}

pub fn main() {
    println!("== §Perf: hot-path microbenchmarks ==");
    for flows in [24, 100, 1000] {
        println!("{}", bench_dispatch(flows, 300).report());
    }
    let (eps, events) = sim_events_per_sec();
    println!("sim engine: {events} events at {:.0} events/s", eps);
    match pjrt_roundtrips() {
        Some(rows) => {
            for (name, s) in rows {
                println!("pjrt exec {name:<12} {:.3} ms", s * 1e3);
            }
        }
        None => println!("pjrt: artifacts not built (run `make artifacts`)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_decision_is_microseconds() {
        let r = bench_dispatch(24, 50);
        // DESIGN.md §7 target: < 5 µs at 24 flows (debug builds are
        // slower; allow 50 µs here — release benches enforce the target).
        assert!(
            r.mean_ns < 50_000.0,
            "dispatch too slow: {:.0} ns",
            r.mean_ns
        );
    }

    #[test]
    fn sim_engine_is_fast() {
        let (eps, events) = sim_events_per_sec();
        assert!(events > 1000);
        assert!(eps > 10_000.0, "sim engine {eps:.0} events/s");
    }
}
