//! §Perf: hot-path microbenchmarks — dispatch decision latency (dense
//! and sparse-activity shapes, plus the naive full-scan baseline), sim
//! engine throughput, PJRT execution round-trip (when artifacts exist).
//! Results feed EXPERIMENTS.md §Perf and are emitted machine-readable to
//! `BENCH_perf.json` so the bench trajectory is tracked across PRs.

use crate::plane::PlaneConfig;
use crate::scheduler::mqfq::reference::NaiveMqfq;
use crate::scheduler::{Invocation, MqfqConfig, MqfqSticky, Policy, PolicyCtx};
use crate::telemetry::{EventKind, Telemetry, TraceEvent};
use crate::types::{FuncId, InvocationId, SEC};
use crate::util::bench::{bench, black_box, BenchResult};
use crate::util::json::{self, Json};
use crate::workload::zipf::{self, ZipfConfig};

/// Shared harness: one enqueue + one dispatch per iteration over a
/// steady backlog confined to the first `n_active` of `n_flows`
/// registered flows (`n_active == n_flows` is the dense shape; a small
/// `n_active` is the Azure-like sparse-activity shape where almost all
/// registered functions are idle).
fn bench_policy_dispatch<P: Policy>(
    mut p: P,
    name: &str,
    n_flows: usize,
    n_active: usize,
    budget_ms: u64,
) -> BenchResult {
    assert!(n_active > 0 && n_active <= n_flows);
    let in_flight = vec![0usize; n_flows];
    // Pre-fill every active flow.
    let mut id = 0u64;
    for f in 0..n_active {
        for _ in 0..4 {
            p.enqueue(
                Invocation {
                    id: InvocationId(id),
                    func: FuncId(f as u32),
                    arrived: 0,
                },
                0,
            );
            id += 1;
        }
    }
    let mut now = SEC;
    let mut rr = 0u32;
    bench(name, budget_ms, || {
        now += 1000;
        // Keep the backlog steady: re-enqueue one item round-robin.
        p.enqueue(
            Invocation {
                id: InvocationId(id),
                func: FuncId(rr % n_active as u32),
                arrived: now,
            },
            now,
        );
        id += 1;
        rr += 1;
        let ctx = PolicyCtx {
            in_flight: &in_flight,
            d: 2,
        };
        let inv = p.dispatch(now, &ctx);
        if let Some(inv) = &inv {
            p.on_complete(inv.func, SEC, now);
        }
        black_box(inv);
    })
}

/// Dispatch-decision latency at a given flow count, every flow backlogged.
pub fn bench_dispatch(n_flows: usize, budget_ms: u64) -> BenchResult {
    bench_policy_dispatch(
        MqfqSticky::new(n_flows, MqfqConfig::default()),
        &format!("mqfq dispatch ({n_flows} flows)"),
        n_flows,
        n_flows,
        budget_ms,
    )
}

/// Sparse-activity shape: `n_flows` registered, only `n_active`
/// backlogged. The decision must stay flat as the registered universe
/// grows — only the backlogged subset may cost anything.
pub fn bench_dispatch_sparse(n_flows: usize, n_active: usize, budget_ms: u64) -> BenchResult {
    bench_policy_dispatch(
        MqfqSticky::new(n_flows, MqfqConfig::default()),
        &format!("mqfq dispatch ({n_flows} flows, {n_active} active)"),
        n_flows,
        n_active,
        budget_ms,
    )
}

/// The pre-refactor O(registered flows) full-scan baseline
/// ([`NaiveMqfq`]), benched for the speedup rows of `BENCH_perf.json`.
pub fn bench_dispatch_naive(n_flows: usize, budget_ms: u64) -> BenchResult {
    bench_policy_dispatch(
        NaiveMqfq::new(n_flows, MqfqConfig::default()),
        &format!("naive dispatch ({n_flows} flows)"),
        n_flows,
        n_flows,
        budget_ms,
    )
}

/// Full-scan baseline on the sparse-activity shape: the naive sweep
/// still walks every *registered* flow per decision, which is exactly
/// the asymptotic gap the index removes.
pub fn bench_dispatch_naive_sparse(
    n_flows: usize,
    n_active: usize,
    budget_ms: u64,
) -> BenchResult {
    bench_policy_dispatch(
        NaiveMqfq::new(n_flows, MqfqConfig::default()),
        &format!("naive dispatch ({n_flows} flows, {n_active} active)"),
        n_flows,
        n_active,
        budget_ms,
    )
}

/// Dispatch with the telemetry record path attached: each decision also
/// performs the steady-state emission set the instrumented plane does —
/// dispatch/exec-start/complete counters, the three latency histograms,
/// and three ring events. The delta against the bare row *is* the
/// telemetry overhead per decision (gated in release benches: ≤ 10% of
/// bare plus a fixed sub-µs floor, and ≤ 5 µs absolute).
pub fn bench_dispatch_telemetry(n_flows: usize, budget_ms: u64) -> BenchResult {
    assert!(n_flows > 0);
    let tel = Telemetry::new(&[1], &["bench".to_string()]);
    let mut p = MqfqSticky::new(n_flows, MqfqConfig::default());
    let in_flight = vec![0usize; n_flows];
    let mut id = 0u64;
    for f in 0..n_flows {
        for _ in 0..4 {
            p.enqueue(
                Invocation {
                    id: InvocationId(id),
                    func: FuncId(f as u32),
                    arrived: 0,
                },
                0,
            );
            id += 1;
        }
    }
    let mut now = SEC;
    let mut rr = 0u32;
    bench(
        &format!("mqfq dispatch+telemetry ({n_flows} flows)"),
        budget_ms,
        || {
            now += 1000;
            p.enqueue(
                Invocation {
                    id: InvocationId(id),
                    func: FuncId(rr % n_flows as u32),
                    arrived: now,
                },
                now,
            );
            id += 1;
            rr += 1;
            let ctx = PolicyCtx {
                in_flight: &in_flight,
                d: 2,
            };
            let inv = p.dispatch(now, &ctx);
            if let Some(inv) = &inv {
                // The plane's steady-state per-invocation record set.
                let m = tel.registry.shard(0);
                m.submitted.inc();
                m.completed.inc();
                m.gpu_warm_starts.inc();
                tel.registry.device(0, 0).unwrap().dispatches.inc();
                m.queue_wait_ns.record(1_000);
                m.exec_ns.record(SEC);
                m.e2e_ns.record(SEC + 1_000);
                tel.emit(
                    TraceEvent::new(now, EventKind::Dispatch, 0)
                        .inv(inv.id.0)
                        .func(inv.func.0)
                        .a(2),
                );
                tel.emit(
                    TraceEvent::new(now, EventKind::ExecStart, 0)
                        .inv(inv.id.0)
                        .func(inv.func.0),
                );
                tel.emit(
                    TraceEvent::new(now + SEC, EventKind::Complete, 0)
                        .inv(inv.id.0)
                        .func(inv.func.0)
                        .a((SEC + 1_000) as i64)
                        .b(SEC as i64),
                );
                p.on_complete(inv.func, SEC, now);
            }
            black_box(inv);
        },
    )
}

/// Sim-engine throughput in events/second on a standard Zipf replay.
pub fn sim_events_per_sec() -> (f64, u64) {
    let (w, t) = zipf::generate(&ZipfConfig {
        n_funcs: 24,
        total_rate: 4.0,
        duration_s: 600.0,
        seed: 3,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let r = crate::sim::replay(w, &t, PlaneConfig::default());
    let wall = t0.elapsed().as_secs_f64();
    (r.events as f64 / wall, r.events)
}

/// One dispatch-bench row of the perf report.
pub struct DispatchRow {
    pub flows: usize,
    pub active: usize,
    pub result: BenchResult,
}

/// The full §Perf measurement set (dispatch shapes + naive baseline +
/// sim throughput), shared by the printed report and `BENCH_perf.json`.
pub struct PerfReport {
    pub dispatch: Vec<DispatchRow>,
    pub naive_1000: BenchResult,
    pub naive_10k_sparse: BenchResult,
    /// Indexed-vs-naive mean decision latency at 1000 dense flows (the
    /// ISSUE-tracked number; constant-factor win — both scan ~1000).
    pub speedup_vs_naive_1000: f64,
    /// Indexed-vs-naive at 10k registered / 100 active (asymptotic win:
    /// the sweep walks 10k registered, the index touches ~100).
    pub speedup_vs_naive_10k_sparse: f64,
    /// Dispatch with the telemetry record path attached (1000 dense
    /// flows) — the instrumented twin of the bare 1000-flow row.
    pub telemetry_on_1000: BenchResult,
    /// Instrumented / bare mean decision latency at 1000 dense flows.
    pub telemetry_overhead_1000: f64,
    pub sim_events: u64,
    pub sim_events_per_sec: f64,
}

impl PerfReport {
    pub fn row(&self, flows: usize, active: usize) -> Option<&BenchResult> {
        self.dispatch
            .iter()
            .find(|r| r.flows == flows && r.active == active)
            .map(|r| &r.result)
    }
}

/// Run every §Perf measurement with the given per-row time budget.
pub fn collect(budget_ms: u64) -> PerfReport {
    let mut dispatch = Vec::new();
    // Dense shapes: every registered flow backlogged.
    for flows in [24usize, 100, 1000] {
        dispatch.push(DispatchRow {
            flows,
            active: flows,
            result: bench_dispatch(flows, budget_ms),
        });
    }
    // Sparse-activity shapes (the Azure-trace regime): 10k registered,
    // ~1% backlogged, and the same absolute backlog at 1k registered so
    // the flat-vs-flow-count comparison holds the work constant.
    for (flows, active) in [(1_000usize, 100usize), (10_000, 100)] {
        dispatch.push(DispatchRow {
            flows,
            active,
            result: bench_dispatch_sparse(flows, active, budget_ms),
        });
    }
    let naive_1000 = bench_dispatch_naive(1000, budget_ms);
    let naive_10k_sparse = bench_dispatch_naive_sparse(10_000, 100, budget_ms);
    let mean_of = |flows: usize, active: usize| {
        dispatch
            .iter()
            .find(|r| r.flows == flows && r.active == active)
            .expect("bench row present")
            .result
            .mean_ns
            .max(1.0)
    };
    let speedup = naive_1000.mean_ns / mean_of(1000, 1000);
    let speedup_sparse = naive_10k_sparse.mean_ns / mean_of(10_000, 100);
    let telemetry_on_1000 = bench_dispatch_telemetry(1000, budget_ms);
    let telemetry_overhead_1000 = telemetry_on_1000.mean_ns / mean_of(1000, 1000);
    let (eps, events) = sim_events_per_sec();
    PerfReport {
        dispatch,
        naive_1000,
        naive_10k_sparse,
        speedup_vs_naive_1000: speedup,
        speedup_vs_naive_10k_sparse: speedup_sparse,
        telemetry_on_1000,
        telemetry_overhead_1000,
        sim_events: events,
        sim_events_per_sec: eps,
    }
}

fn bench_json(b: &BenchResult) -> Json {
    Json::Obj(vec![
        ("name".into(), Json::str(b.name.clone())),
        ("iters".into(), Json::Int(b.iters as i64)),
        ("mean_ns".into(), Json::Num(b.mean_ns)),
        ("min_ns".into(), Json::Num(b.min_ns)),
        ("max_ns".into(), Json::Num(b.max_ns)),
    ])
}

/// Machine-readable form of the report (`BENCH_perf.json`).
pub fn report_json(r: &PerfReport) -> Json {
    let rows = r
        .dispatch
        .iter()
        .map(|row| {
            Json::Obj(vec![
                ("flows".into(), Json::Int(row.flows as i64)),
                ("active".into(), Json::Int(row.active as i64)),
                ("impl".into(), Json::str("indexed")),
                ("telemetry".into(), Json::str("off")),
                ("bench".into(), bench_json(&row.result)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::str("mqfq-bench-perf/v1")),
        ("dispatch".into(), Json::Arr(rows)),
        (
            "dispatch_naive_1000".into(),
            Json::Obj(vec![
                ("flows".into(), Json::Int(1000)),
                ("active".into(), Json::Int(1000)),
                ("impl".into(), Json::str("naive")),
                ("bench".into(), bench_json(&r.naive_1000)),
            ]),
        ),
        (
            "dispatch_naive_10k_sparse".into(),
            Json::Obj(vec![
                ("flows".into(), Json::Int(10_000)),
                ("active".into(), Json::Int(100)),
                ("impl".into(), Json::str("naive")),
                ("bench".into(), bench_json(&r.naive_10k_sparse)),
            ]),
        ),
        (
            "speedup_vs_naive_1000".into(),
            Json::Num(r.speedup_vs_naive_1000),
        ),
        (
            "speedup_vs_naive_10k_sparse".into(),
            Json::Num(r.speedup_vs_naive_10k_sparse),
        ),
        (
            "dispatch_telemetry_1000".into(),
            Json::Obj(vec![
                ("flows".into(), Json::Int(1000)),
                ("active".into(), Json::Int(1000)),
                ("impl".into(), Json::str("indexed")),
                ("telemetry".into(), Json::str("on")),
                ("bench".into(), bench_json(&r.telemetry_on_1000)),
            ]),
        ),
        (
            "telemetry_overhead_1000".into(),
            Json::Num(r.telemetry_overhead_1000),
        ),
        (
            "sim".into(),
            Json::Obj(vec![
                ("events".into(), Json::Int(r.sim_events as i64)),
                ("events_per_sec".into(), Json::Num(r.sim_events_per_sec)),
            ]),
        ),
    ])
}

/// PJRT execution round-trip per catalog artifact (None if artifacts
/// have not been built).
pub fn pjrt_roundtrips() -> Option<Vec<(String, f64)>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        return None;
    }
    let mut rt = crate::runtime::PjrtRuntime::new(&dir).ok()?;
    let names = rt.load_all().ok()?;
    let mut out = Vec::new();
    for name in names {
        rt.execute(&name).ok()?; // warm
        let t0 = std::time::Instant::now();
        let iters = 5;
        for _ in 0..iters {
            rt.execute(&name).ok()?;
        }
        out.push((name, t0.elapsed().as_secs_f64() / iters as f64));
    }
    Some(out)
}

pub fn main() {
    println!("== §Perf: hot-path microbenchmarks ==");
    let report = collect(300);
    for row in &report.dispatch {
        println!("{}", row.result.report());
    }
    println!("{}", report.naive_1000.report());
    println!("{}", report.naive_10k_sparse.report());
    println!(
        "indexed vs naive: {:.1}x @1000 dense, {:.1}x @10k/1% sparse",
        report.speedup_vs_naive_1000, report.speedup_vs_naive_10k_sparse
    );
    println!("{}", report.telemetry_on_1000.report());
    println!(
        "telemetry overhead: {:.2}x bare dispatch @1000 dense",
        report.telemetry_overhead_1000
    );
    println!(
        "sim engine: {} events at {:.0} events/s",
        report.sim_events, report.sim_events_per_sec
    );
    match json::write_file("BENCH_perf.json", &report_json(&report)) {
        Ok(()) => println!("wrote BENCH_perf.json"),
        Err(e) => println!("BENCH_perf.json not written: {e}"),
    }
    match pjrt_roundtrips() {
        Some(rows) => {
            for (name, s) in rows {
                println!("pjrt exec {name:<12} {:.3} ms", s * 1e3);
            }
        }
        None => println!("pjrt: artifacts not built (run `make artifacts`)"),
    }

    // Release-bench regression gates (debug builds are untimed): the
    // decision must be microseconds *flat* in the registered-flow count
    // under sparse activity, and the index rebuild must beat the
    // full-scan baseline decisively at 1000 dense flows.
    if !cfg!(debug_assertions) {
        let s1k = report.row(1_000, 100).expect("sparse 1k row").mean_ns;
        let s10k = report.row(10_000, 100).expect("sparse 10k row").mean_ns;
        assert!(
            s10k <= 5_000.0,
            "sparse 10k-flow decision {s10k:.0} ns exceeds the 5 µs target"
        );
        // Same backlog (100 flows) at 10× the registered universe must
        // cost about the same; 4× + a timer-noise floor is the alarm line.
        assert!(
            s10k <= 4.0 * s1k.max(250.0),
            "decision latency not flat vs registered flows: {s1k:.0} ns @1k vs {s10k:.0} ns @10k"
        );
        // Asymptotic gate: the sweep walks all 10k registered flows,
        // the index ~100 — this one is structurally guaranteed.
        assert!(
            report.speedup_vs_naive_10k_sparse >= 10.0,
            "indexed dispatch only {:.1}x faster than the full-scan baseline at 10k/1% sparse",
            report.speedup_vs_naive_10k_sparse
        );
        // Constant-factor gate at 1000 dense flows (both scan ~1000;
        // the index removes the extra sweeps + the candidate Vec alloc).
        assert!(
            report.speedup_vs_naive_1000 >= 10.0,
            "indexed dispatch only {:.1}x faster than the full-scan baseline at 1000 flows",
            report.speedup_vs_naive_1000
        );
        // Telemetry gates: the instrumented decision stays within 10%
        // of bare (plus a fixed 250 ns floor — at sub-µs decisions a
        // relative bound alone is timer noise) and under the same 5 µs
        // absolute target as the scheduler itself.
        let bare = report.row(1_000, 1_000).expect("dense 1k row").mean_ns;
        let instrumented = report.telemetry_on_1000.mean_ns;
        assert!(
            instrumented <= 5_000.0,
            "instrumented dispatch {instrumented:.0} ns exceeds the 5 µs target"
        );
        assert!(
            instrumented <= 1.10 * bare + 250.0,
            "telemetry record path costs too much: {bare:.0} ns bare vs \
             {instrumented:.0} ns instrumented"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_decision_is_microseconds() {
        let r = bench_dispatch(24, 50);
        // DESIGN.md §7 target: < 5 µs at 24 flows (debug builds are
        // slower; allow 50 µs here — release benches enforce the target).
        assert!(
            r.mean_ns < 50_000.0,
            "dispatch too slow: {:.0} ns",
            r.mean_ns
        );
    }

    #[test]
    fn sparse_shape_runs_and_stays_fast_in_debug() {
        // 10k registered flows, 1% backlogged: even a debug build must
        // stay far under the naive full-scan cost (which sweeps all 10k
        // flows per decision).
        let r = bench_dispatch_sparse(10_000, 100, 50);
        assert!(r.iters > 0);
        // Generous debug-mode bound (release gates live in main()): a
        // naive 10k-flow sweep costs well over this even unloaded, so
        // the assert still catches an accidental O(n) reintroduction
        // without flaking on contended CI machines.
        assert!(
            r.mean_ns < 1_000_000.0,
            "sparse dispatch too slow: {:.0} ns",
            r.mean_ns
        );
    }

    #[test]
    fn naive_baseline_runs() {
        let r = bench_dispatch_naive(100, 20);
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn telemetry_instrumented_dispatch_runs_and_stays_bounded_in_debug() {
        let r = bench_dispatch_telemetry(100, 50);
        assert!(r.iters > 0);
        // Debug bound only (the 10%-of-bare and 5 µs gates are release
        // benches in main()): the record path must stay microseconds.
        assert!(
            r.mean_ns < 1_000_000.0,
            "instrumented dispatch too slow: {:.0} ns",
            r.mean_ns
        );
    }

    #[test]
    fn report_json_has_the_tracked_fields() {
        // Synthetic report: exercising the JSON shape does not need the
        // (expensive) real measurements.
        let fake = |name: &str| BenchResult {
            name: name.to_string(),
            iters: 10,
            mean_ns: 1500.0,
            min_ns: 900.0,
            max_ns: 4000.0,
        };
        let report = PerfReport {
            dispatch: vec![DispatchRow {
                flows: 24,
                active: 24,
                result: fake("mqfq dispatch (24 flows)"),
            }],
            naive_1000: fake("naive dispatch (1000 flows)"),
            naive_10k_sparse: fake("naive dispatch (10000 flows, 100 active)"),
            speedup_vs_naive_1000: 12.5,
            speedup_vs_naive_10k_sparse: 60.0,
            telemetry_on_1000: fake("mqfq dispatch+telemetry (1000 flows)"),
            telemetry_overhead_1000: 1.05,
            sim_events: 12345,
            sim_events_per_sec: 1.0e6,
        };
        let doc = report_json(&report).render();
        for key in [
            "\"schema\"",
            "\"dispatch\"",
            "\"dispatch_naive_1000\"",
            "\"dispatch_naive_10k_sparse\"",
            "\"speedup_vs_naive_1000\"",
            "\"speedup_vs_naive_10k_sparse\"",
            "\"dispatch_telemetry_1000\"",
            "\"telemetry_overhead_1000\"",
            "\"telemetry\"",
            "\"events_per_sec\"",
            "\"mean_ns\"",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
        // And it lands on disk where main() writes it.
        let path = std::env::temp_dir().join("mqfq_bench_perf_test.json");
        json::write_file(&path, &report_json(&report)).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("mqfq-bench-perf/v1"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sim_engine_is_fast() {
        let (eps, events) = sim_events_per_sec();
        assert!(events > 1000);
        assert!(eps > 10_000.0, "sim engine {eps:.0} events/s");
    }
}
