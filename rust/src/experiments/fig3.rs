//! Figure 3: execution-latency overhead of the CUDA interposition shim
//! (UVM substitution of cuMemAlloc). Warm invocations per function with
//! the shim on vs off; most functions see ≤5%, srad ~30%.

use crate::plane::PlaneConfig;
use crate::types::{secs, StartKind};
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::workload::catalog::CATALOG;
use crate::workload::trace::{Trace, TraceEvent, Workload};

#[derive(Debug, Clone)]
pub struct Row {
    pub name: &'static str,
    pub no_shim_s: f64,
    pub shim_s: f64,
    pub overhead_pct: f64,
}

/// Warm execution time of one function, with/without the shim,
/// averaged over `trials` warm invocations (paper: 10 trials).
fn warm_exec(class: &'static crate::workload::FuncClass, shim: bool, trials: usize) -> f64 {
    let mut w = Workload::default();
    let f = w.register(class, 0, 10.0);
    let mut t = Trace::default();
    let gap = class.gpu_cold_s() + 5.0;
    for i in 0..=trials {
        t.events.push(TraceEvent {
            at: secs(i as f64 * gap),
            func: f,
        });
    }
    let cfg = PlaneConfig {
        shim,
        d: 1,
        ..Default::default()
    };
    let r = crate::sim::replay(w, &t, cfg);
    let warm: Vec<f64> = r
        .recorder()
        .records
        .iter()
        .filter(|rec| rec.start_kind != StartKind::Cold)
        .map(|rec| rec.exec_s())
        .collect();
    assert_eq!(warm.len(), trials, "{}", class.name);
    crate::util::stats::mean(&warm)
}

pub fn rows() -> Vec<Row> {
    CATALOG
        .iter()
        .map(|class| {
            let off = warm_exec(class, false, 10);
            let on = warm_exec(class, true, 10);
            Row {
                name: class.name,
                no_shim_s: off,
                shim_s: on,
                overhead_pct: (on / off - 1.0) * 100.0,
            }
        })
        .collect()
}

pub fn main() {
    println!("== Figure 3: UVM interposition shim overhead (10 warm trials) ==");
    let rows = rows();
    let mut t = Table::new(&["Function", "no-shim(s)", "shim(s)", "overhead%"]);
    let mut csv = CsvWriter::create(
        "results/fig3.csv",
        &["function", "no_shim_s", "shim_s", "overhead_pct"],
    )
    .unwrap();
    for r in &rows {
        t.row(&[
            r.name.to_string(),
            format!("{:.3}", r.no_shim_s),
            format!("{:.3}", r.shim_s),
            format!("{:.1}", r.overhead_pct),
        ]);
        csv.rowv(&[
            r.name.to_string(),
            format!("{:.4}", r.no_shim_s),
            format!("{:.4}", r.shim_s),
            format!("{:.2}", r.overhead_pct),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    print!("{}", t.render());
    println!("(paper Fig 3: negligible for most functions, srad ≈ 30%)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srad_is_outlier_rest_small() {
        let rows = rows();
        for r in &rows {
            if r.name == "srad" {
                assert!(
                    (r.overhead_pct - 30.0).abs() < 3.0,
                    "srad overhead {}",
                    r.overhead_pct
                );
            } else {
                assert!(
                    r.overhead_pct < 10.0,
                    "{}: overhead {}",
                    r.name,
                    r.overhead_pct
                );
                assert!(r.overhead_pct >= 0.0);
            }
        }
    }
}
