//! Nonblocking readiness event loop for the serving front end: one
//! poller thread drives *every* connection — accept, read, parse,
//! dispatch, and batched write-backs — replacing the PR-4/PR-5
//! accept-thread + thread-per-connection model whose thread count grew
//! with fan-in. At 10k open connections the server still runs
//! `shards × workers` executor threads plus O(1) loop/timer threads.
//!
//! ## Design
//!
//! * **epoll, hand-rolled.** No external crates (vendored-crates
//!   discipline): a small FFI surface over `epoll_create1` /
//!   `epoll_ctl` / `epoll_wait` / `eventfd` (std already links libc on
//!   Linux). Level-triggered; `EPOLLOUT` is armed only while a
//!   connection has unflushed outbound bytes.
//! * **Per-connection reuse buffers.** Each connection owns a read
//!   buffer (lines are scanned in place; the dispatch path reuses one
//!   loop-wide line buffer and the zero-copy `JVal` parser borrows
//!   from it) and a single outbound byte queue flushed with one
//!   `write` per readiness — the writev-style batch: every reply
//!   appended since the last flush leaves in one syscall.
//! * **Deferred replies.** Blocking verbs (sync `invoke`, `wait`) do
//!   not block the loop: dispatch registers a completion subscription
//!   ([`crate::api::CompletionSink`]) plus an entry in a deadline heap,
//!   and the reply is encoded when the ticket resolves (or the deadline
//!   fires). Completions arrive from executor threads over the
//!   [`CompletionBus`] and wake the poller via an `eventfd`.
//! * **Pipelining + out-of-order.** Every complete line is dispatched
//!   as it is parsed; replies carry the request's optional `"id"` tag
//!   so a pipelined client can match them out of order. Lockstep
//!   clients (one request in flight, no `"id"`) observe byte-identical
//!   replies to the old blocking loop — pinned by test.
//! * **Slow-client protection.** A reader that stops draining its
//!   socket would otherwise pin an unbounded outbound queue; past
//!   [`LoopConfig::max_outbound`] queued bytes the connection is
//!   disconnected with a best-effort structured
//!   `ApiError::SlowConsumer` line.
//!
//! ## Ownership
//!
//! The loop thread owns the listener, the epoll instance, and every
//! connection's buffers. Executor threads touch only the
//! [`CompletionBus`] (a mutex-guarded notice vector + eventfd write).
//! Ticket claim semantics are preserved: a completion is *claimed*
//! (removed from the ticket table) only after its reply bytes are
//! queued to a live subscriber; a deadline-expired or disconnected
//! waiter leaves the ticket redeemable, exactly like the blocking
//! wait path.

use std::collections::BinaryHeap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::types::{ApiError, InvokeOutcome, Response, Ticket};
use crate::api::wire::{self, LoopAction, ReplyFormat};
use crate::api::{CompletionSink, Frontend};
use crate::telemetry::Telemetry;

// ---------------------------------------------------------------------------
// Raw epoll / eventfd FFI (std links libc; no new dependencies).
// ---------------------------------------------------------------------------

/// Mirror of `struct epoll_event`. On x86-64 Linux the kernel ABI packs
/// this to 12 bytes (`__attribute__((packed))` in the libc header), so
/// the packed repr is required for `epoll_wait` to fill the array
/// correctly.
#[repr(C, packed)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

const EFD_NONBLOCK: i32 = 0o4000;

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

const RLIMIT_NOFILE: i32 = 7;

/// Raise the process's open-file soft limit toward `want` (clamped to
/// the hard limit) and return the resulting soft limit. The 10k-
/// connection bench needs ~2×10k descriptors (client + server ends on
/// loopback); default soft limits are often 1024. Best-effort: on any
/// failure the current limit is returned unchanged.
pub fn raise_nofile_limit(want: u64) -> u64 {
    unsafe {
        let mut lim = RLimit {
            rlim_cur: 0,
            rlim_max: 0,
        };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 1024;
        }
        if lim.rlim_cur >= want {
            return lim.rlim_cur;
        }
        let target = want.min(lim.rlim_max);
        let new = RLimit {
            rlim_cur: target,
            rlim_max: lim.rlim_max,
        };
        if setrlimit(RLIMIT_NOFILE, &new) == 0 {
            target
        } else {
            lim.rlim_cur
        }
    }
}

/// Minimal owned epoll instance.
struct Poller {
    epfd: i32,
}

impl Poller {
    fn new() -> io::Result<Self> {
        let epfd = unsafe { epoll_create1(0) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { epfd })
    }

    fn add(&self, fd: i32, token: u64, events: u32) -> io::Result<()> {
        let mut ev = EpollEvent {
            events,
            data: token,
        };
        if unsafe { epoll_ctl(self.epfd, EPOLL_CTL_ADD, fd, &mut ev) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn modify(&self, fd: i32, token: u64, events: u32) {
        let mut ev = EpollEvent {
            events,
            data: token,
        };
        unsafe { epoll_ctl(self.epfd, EPOLL_CTL_MOD, fd, &mut ev) };
    }

    fn del(&self, fd: i32) {
        let mut ev = EpollEvent { events: 0, data: 0 };
        unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) };
    }

    /// Wait for readiness; EINTR retries with the same timeout.
    fn wait(&self, events: &mut [EpollEvent], timeout: Duration) -> usize {
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        loop {
            let n = unsafe {
                epoll_wait(self.epfd, events.as_mut_ptr(), events.len() as i32, ms)
            };
            if n >= 0 {
                return n as usize;
            }
            if io::Error::last_os_error().kind() != io::ErrorKind::Interrupted {
                return 0;
            }
        }
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        unsafe { close(self.epfd) };
    }
}

// ---------------------------------------------------------------------------
// Completion bus: executor threads → poller thread.
// ---------------------------------------------------------------------------

/// One resolved ticket bound for one connection's pending reply slot.
struct Notice {
    conn: u64,
    tag: u64,
    ticket: Ticket,
    result: Result<InvokeOutcome, ApiError>,
}

/// The loop's [`CompletionSink`]: executor threads push a notice under
/// a short mutex and kick the poller's `eventfd`. The poller drains the
/// vector each wakeup. `conn` tokens carry a generation stamp (see
/// [`conn_token`]) so a notice for a closed-and-reused slot is dropped
/// instead of misdelivered.
pub struct CompletionBus {
    notices: Mutex<Vec<Notice>>,
    wake_fd: i32,
}

impl CompletionBus {
    fn new() -> io::Result<Self> {
        let wake_fd = unsafe { eventfd(0, EFD_NONBLOCK) };
        if wake_fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self {
            notices: Mutex::new(Vec::new()),
            wake_fd,
        })
    }

    fn kick(&self) {
        let one = 1u64.to_ne_bytes();
        unsafe { write(self.wake_fd, one.as_ptr(), one.len()) };
    }

    fn drain_wake(&self) {
        let mut buf = [0u8; 8];
        unsafe { read(self.wake_fd, buf.as_mut_ptr(), buf.len()) };
    }

    fn take(&self) -> Vec<Notice> {
        std::mem::take(&mut self.notices.lock().unwrap())
    }
}

impl CompletionSink for CompletionBus {
    fn complete(
        &self,
        conn: u64,
        tag: u64,
        ticket: Ticket,
        result: Result<InvokeOutcome, ApiError>,
    ) {
        self.notices.lock().unwrap().push(Notice {
            conn,
            tag,
            ticket,
            result,
        });
        self.kick();
    }
}

impl Drop for CompletionBus {
    fn drop(&mut self) {
        unsafe { close(self.wake_fd) };
    }
}

/// Pack a slab slot + its generation into the `u64` a
/// [`CompletionSink`] notice addresses.
fn conn_token(slot: usize, gen: u32) -> u64 {
    ((gen as u64) << 32) | slot as u64
}

fn token_slot(token: u64) -> usize {
    (token & 0xFFFF_FFFF) as usize
}

fn token_gen(token: u64) -> u32 {
    (token >> 32) as u32
}

/// Monotone per-process connection generation: unique for every
/// accepted connection, so a recycled slab slot never matches a stale
/// notice's token.
fn next_gen() -> u32 {
    static ODOMETER: AtomicU32 = AtomicU32::new(1);
    ODOMETER.fetch_add(1, Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Connections.
// ---------------------------------------------------------------------------

/// A reply owed to this connection once a ticket resolves.
struct PendingReply {
    tag: u64,
    ticket: Ticket,
    t0: Instant,
    format: ReplyFormat,
    /// Push-subscription notice (`{"type":"push"}`) vs a deferred
    /// request/reply (sync invoke, wait).
    push: bool,
}

struct Conn {
    stream: TcpStream,
    /// Generation stamp; stale completion notices carry an old one.
    gen: u32,
    /// Inbound bytes; complete lines are carved off the front.
    rbuf: Vec<u8>,
    /// Prefix of `rbuf` already scanned for a newline.
    scan: usize,
    /// Outbound byte queue; one `write` per flush drains
    /// `out[out_pos..]` — the batched writev-style flush.
    out: Vec<u8>,
    out_pos: usize,
    /// Whether EPOLLOUT is currently armed for this connection.
    want_write: bool,
    /// Replies deferred on ticket completion, any order.
    pending: Vec<PendingReply>,
    /// Per-connection tag sequence for pending replies.
    next_tag: u64,
    /// Graceful close requested (bye sent): close once flushed.
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream, gen: u32) -> Self {
        Self {
            stream,
            gen,
            rbuf: Vec::with_capacity(1024),
            scan: 0,
            out: Vec::with_capacity(1024),
            out_pos: 0,
            want_write: false,
            pending: Vec::new(),
            next_tag: 0,
            closing: false,
        }
    }

    fn queued(&self) -> usize {
        self.out.len() - self.out_pos
    }
}

/// Min-heap entry: `(fire_at, conn_token, tag)` under `Reverse`.
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct DeadlineAt(std::cmp::Reverse<(Instant, u64, u64)>);

/// What a borrow-scoped I/O phase decided the caller must do next.
enum After {
    Nothing,
    Close,
    ArmWrite,
    DisarmWrite,
}

// ---------------------------------------------------------------------------
// Loop configuration.
// ---------------------------------------------------------------------------

/// Tunables for one serving event loop.
#[derive(Debug, Clone, Copy)]
pub struct LoopConfig {
    /// Per-connection outbound high-water mark, bytes. A connection
    /// whose unflushed queue exceeds this is disconnected with a
    /// structured `slow-consumer` error (best-effort delivery).
    pub max_outbound: usize,
    /// Inbound buffer bound, bytes; a line longer than this loses
    /// framing and closes the connection.
    pub max_line: usize,
    /// Open-connection cap; accepts beyond it are dropped immediately.
    pub max_conns: usize,
}

impl Default for LoopConfig {
    fn default() -> Self {
        Self {
            max_outbound: 256 * 1024,
            max_line: 256 * 1024,
            max_conns: 65_536,
        }
    }
}

// ---------------------------------------------------------------------------
// The event loop.
// ---------------------------------------------------------------------------

const TOKEN_LISTENER: u64 = u64::MAX;
const TOKEN_WAKE: u64 = u64::MAX - 1;
/// Idle epoll timeout: bounds how stale the `running` shutdown check
/// can get when no deadline is armed.
const IDLE_TIMEOUT: Duration = Duration::from_millis(50);

/// One serving event loop: owns the listener and every connection.
/// Constructed on the caller's thread (so bind/epoll errors surface
/// synchronously), then driven by [`run`](EventLoop::run) on a
/// dedicated thread.
pub struct EventLoop<F: Frontend> {
    frontend: F,
    listener: TcpListener,
    poller: Poller,
    bus: Arc<CompletionBus>,
    running: Arc<AtomicBool>,
    tel: Option<Arc<Telemetry>>,
    cfg: LoopConfig,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    open: i64,
    deadlines: BinaryHeap<DeadlineAt>,
    /// Reused encode scratch (replies are encoded here, then appended
    /// to the connection's outbound queue).
    scratch: String,
    /// Reused line buffer (one inbound line at a time; the borrowed
    /// `JVal` parse points into it).
    linebuf: Vec<u8>,
}

impl<F: Frontend> EventLoop<F> {
    pub fn new(
        frontend: F,
        listener: TcpListener,
        running: Arc<AtomicBool>,
        tel: Option<Arc<Telemetry>>,
        cfg: LoopConfig,
    ) -> io::Result<Self> {
        listener.set_nonblocking(true)?;
        let poller = Poller::new()?;
        let bus = Arc::new(CompletionBus::new()?);
        poller.add(fd_of(&listener), TOKEN_LISTENER, EPOLLIN)?;
        poller.add(bus.wake_fd, TOKEN_WAKE, EPOLLIN)?;
        Ok(Self {
            frontend,
            listener,
            poller,
            bus,
            running,
            tel,
            cfg,
            conns: Vec::new(),
            free: Vec::new(),
            open: 0,
            deadlines: BinaryHeap::new(),
            scratch: String::with_capacity(512),
            linebuf: Vec::with_capacity(512),
        })
    }

    fn serving(&self) -> Option<&crate::telemetry::ServingMetrics> {
        self.tel.as_ref().map(|t| t.registry.serving())
    }

    /// Drive the loop until the shared `running` flag clears. Consumes
    /// the loop; every connection drops on exit.
    pub fn run(mut self) {
        let mut events = [EpollEvent { events: 0, data: 0 }; 256];
        while self.running.load(Ordering::SeqCst) {
            let timeout = self
                .next_deadline()
                .map(|at| at.saturating_duration_since(Instant::now()))
                .unwrap_or(IDLE_TIMEOUT)
                .min(IDLE_TIMEOUT);
            let n = self.poller.wait(&mut events, timeout);
            for ev in events.iter().take(n) {
                let (token, ready) = (ev.data, ev.events);
                match token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKE => self.bus.drain_wake(),
                    t => self.conn_ready(token_slot(t), ready),
                }
            }
            self.deliver_completions();
            self.fire_deadlines();
        }
    }

    fn next_deadline(&self) -> Option<Instant> {
        self.deadlines
            .peek()
            .map(|DeadlineAt(std::cmp::Reverse((at, _, _)))| *at)
    }

    // -- accept ------------------------------------------------------------

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => self.register(stream),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }

    fn register(&mut self, stream: TcpStream) {
        if self.open as usize >= self.cfg.max_conns || stream.set_nonblocking(true).is_err() {
            return; // drop: over cap or unusable socket
        }
        let _ = stream.set_nodelay(true);
        let fd = fd_of(&stream);
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.conns.push(None);
                self.conns.len() - 1
            }
        };
        if self.poller.add(fd, slot as u64, EPOLLIN).is_err() {
            self.free.push(slot);
            return;
        }
        self.conns[slot] = Some(Conn::new(stream, next_gen()));
        self.open += 1;
        if let Some(m) = self.serving() {
            m.accepted_connections.inc();
            m.open_connections.set(self.open);
        }
    }

    // -- readiness ---------------------------------------------------------

    fn conn_ready(&mut self, slot: usize, ready: u32) {
        if self.conns.get(slot).map_or(true, Option::is_none) {
            return; // closed earlier in this batch
        }
        if ready & (EPOLLERR | EPOLLHUP) != 0 {
            self.close_conn(slot);
            return;
        }
        if ready & EPOLLOUT != 0 && !self.flush(slot) {
            return;
        }
        if ready & EPOLLIN != 0 {
            self.read_ready(slot);
        }
    }

    /// Drain the socket into the connection's read buffer, then
    /// dispatch every complete line.
    fn read_ready(&mut self, slot: usize) {
        let mut chunk = [0u8; 16 * 1024];
        let mut closed = false;
        {
            let max_line = self.cfg.max_line;
            let Some(conn) = self.conns[slot].as_mut() else {
                return;
            };
            loop {
                match conn.stream.read(&mut chunk) {
                    Ok(0) => {
                        closed = true;
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&chunk[..n]);
                        if conn.rbuf.len() > max_line {
                            closed = true; // framing unrecoverable
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        closed = true;
                        break;
                    }
                }
            }
        }
        if closed {
            self.close_conn(slot);
            return;
        }
        self.dispatch_lines(slot);
    }

    /// Carve complete lines off the read buffer and dispatch each;
    /// record the batch depth (requests handled per readiness — the
    /// pipelining signal).
    fn dispatch_lines(&mut self, slot: usize) {
        let mut depth = 0u64;
        let mut linebuf = std::mem::take(&mut self.linebuf);
        loop {
            {
                let Some(conn) = self.conns[slot].as_mut() else {
                    break;
                };
                let Some(nl) = conn.rbuf[conn.scan..].iter().position(|&b| b == b'\n') else {
                    conn.scan = conn.rbuf.len();
                    break;
                };
                let end = conn.scan + nl;
                linebuf.clear();
                linebuf.extend_from_slice(&conn.rbuf[..end]);
                conn.rbuf.drain(..=end);
                conn.scan = 0;
            }
            depth += 1;
            // The blocking loop's read_line fails the connection on
            // invalid UTF-8; mirror that.
            let keep_going = match std::str::from_utf8(&linebuf) {
                Ok(s) => {
                    let s = s.trim();
                    if s.is_empty() {
                        true
                    } else {
                        self.dispatch_line(slot, s)
                    }
                }
                Err(_) => {
                    self.close_conn(slot);
                    false
                }
            };
            if !keep_going {
                break;
            }
        }
        self.linebuf = linebuf;
        if depth > 0 {
            if let Some(m) = self.serving() {
                m.pipeline_depth.record(depth);
            }
            if self.conns.get(slot).and_then(Option::as_ref).is_some() {
                self.flush(slot);
            }
        }
    }

    /// Dispatch one request line; returns false when the connection was
    /// closed (stop consuming its buffer).
    fn dispatch_line(&mut self, slot: usize, line: &str) -> bool {
        self.scratch.clear();
        let action = wire::handle_line_deferred(&self.frontend, line, &mut self.scratch);
        {
            let Some(conn) = self.conns[slot].as_mut() else {
                return false;
            };
            conn.out.extend_from_slice(self.scratch.as_bytes());
        }
        match action {
            LoopAction::Replied { close } => {
                if close {
                    if let Some(conn) = self.conns[slot].as_mut() {
                        conn.closing = true;
                    }
                    self.flush(slot); // closes once drained
                    return false;
                }
            }
            LoopAction::AwaitCompletion {
                ticket,
                deadline,
                format,
            } => {
                self.defer_reply(slot, ticket, deadline, format, false);
            }
            LoopAction::Subscribe { ticket, id } => {
                if let Some(m) = self.serving() {
                    m.push_subscriptions.inc();
                }
                self.defer_reply(slot, ticket, None, ReplyFormat::V1 { id }, true);
            }
        }
        self.enforce_outbound_cap(slot)
    }

    /// Register a pending reply + completion subscription for `ticket`.
    fn defer_reply(
        &mut self,
        slot: usize,
        ticket: Ticket,
        deadline: Option<Duration>,
        format: ReplyFormat,
        push: bool,
    ) {
        let now = Instant::now();
        let (gen, tag) = {
            let Some(conn) = self.conns[slot].as_mut() else {
                return;
            };
            let tag = conn.next_tag;
            conn.next_tag += 1;
            conn.pending.push(PendingReply {
                tag,
                ticket,
                t0: now,
                format,
                push,
            });
            (conn.gen, tag)
        };
        let token = conn_token(slot, gen);
        if let Some(d) = deadline {
            self.deadlines
                .push(DeadlineAt(std::cmp::Reverse((now + d, token, tag))));
        }
        let sink: Arc<dyn CompletionSink> = self.bus.clone();
        if let Err(e) = self.frontend.subscribe(ticket, sink, token, tag) {
            // Unknown/evicted ticket (or a frontend without push
            // support): the error is the reply, immediately.
            self.resolve_pending(slot, tag, ticket, Err(e), false);
        }
    }

    // -- completions -------------------------------------------------------

    fn deliver_completions(&mut self) {
        for n in self.bus.take() {
            let slot = token_slot(n.conn);
            let alive = self
                .conns
                .get(slot)
                .and_then(Option::as_ref)
                .map_or(false, |c| {
                    c.gen == token_gen(n.conn) && c.pending.iter().any(|p| p.tag == n.tag)
                });
            if !alive {
                // Subscriber disconnected (or deadline already
                // answered) before the completion: the ticket stays
                // redeemable elsewhere, the notice is dropped.
                if let Some(m) = self.serving() {
                    m.push_dropped.inc();
                }
                continue;
            }
            self.resolve_pending(slot, n.tag, n.ticket, n.result, true);
            if self.conns.get(slot).and_then(Option::as_ref).is_some() {
                self.flush(slot);
            }
        }
    }

    /// Encode and queue the reply for pending `tag`; when `claim` is
    /// set, the delivered ticket is then removed from the table (the
    /// event-loop analog of a blocking wait's claim-on-return).
    fn resolve_pending(
        &mut self,
        slot: usize,
        tag: u64,
        ticket: Ticket,
        result: Result<InvokeOutcome, ApiError>,
        claim: bool,
    ) {
        let p = {
            let Some(conn) = self.conns[slot].as_mut() else {
                return;
            };
            let Some(idx) = conn.pending.iter().position(|p| p.tag == tag) else {
                return;
            };
            conn.pending.swap_remove(idx)
        };
        self.scratch.clear();
        match (&p.format, result) {
            (ReplyFormat::V1 { id }, Ok(o)) => {
                let resp = if p.push {
                    Response::Push(o)
                } else {
                    Response::Done(o)
                };
                wire::encode_response_tagged_into(&resp, *id, &mut self.scratch);
            }
            (ReplyFormat::V1 { id }, Err(e)) => {
                wire::encode_response_tagged_into(&Response::Error(e), *id, &mut self.scratch);
            }
            (ReplyFormat::Legacy, Ok(o)) => {
                wire::encode_legacy_outcome_into(&o, &mut self.scratch);
            }
            (ReplyFormat::Legacy, Err(e)) => {
                wire::encode_legacy_error_into(&e, &mut self.scratch);
            }
        }
        self.scratch.push('\n');
        {
            let Some(conn) = self.conns[slot].as_mut() else {
                return;
            };
            conn.out.extend_from_slice(self.scratch.as_bytes());
        }
        if claim {
            if p.push {
                if let Some(m) = self.serving() {
                    m.push_notifications.inc();
                }
            }
            // Claim after delivery; a failed ticket resolves via the
            // same path (poll surfaces and removes the stored error).
            let _ = self.frontend.poll(ticket);
        }
        self.enforce_outbound_cap(slot);
    }

    // -- deadlines ---------------------------------------------------------

    fn fire_deadlines(&mut self) {
        let now = Instant::now();
        loop {
            match self.deadlines.peek() {
                Some(DeadlineAt(std::cmp::Reverse((at, _, _)))) if *at <= now => {}
                _ => break,
            }
            let DeadlineAt(std::cmp::Reverse((_, token, tag))) =
                self.deadlines.pop().expect("peeked entry");
            let slot = token_slot(token);
            let live = self
                .conns
                .get(slot)
                .and_then(Option::as_ref)
                .map_or(false, |c| c.gen == token_gen(token));
            if !live {
                continue;
            }
            let hit = self.conns[slot]
                .as_ref()
                .and_then(|c| c.pending.iter().find(|p| p.tag == tag))
                .map(|p| (p.ticket, (p.t0.elapsed().as_secs_f64() * 1e3) as u64));
            let Some((ticket, waited_ms)) = hit else {
                continue; // already answered by its completion
            };
            // Deadline trips do NOT claim: the invocation keeps
            // running and the ticket stays redeemable (parity with
            // the blocking wait path).
            self.resolve_pending(
                slot,
                tag,
                ticket,
                Err(ApiError::DeadlineExceeded {
                    waited_ms,
                    ticket: Some(ticket),
                }),
                false,
            );
            if self.conns.get(slot).and_then(Option::as_ref).is_some() {
                self.flush(slot);
            }
        }
    }

    // -- writes ------------------------------------------------------------

    /// Flush the outbound queue with batched writes; returns false when
    /// the connection was closed. Arms/disarms EPOLLOUT as needed.
    fn flush(&mut self, slot: usize) -> bool {
        let after = {
            let Some(conn) = self.conns[slot].as_mut() else {
                return false;
            };
            let mut failed = false;
            while conn.out_pos < conn.out.len() {
                match conn.stream.write(&conn.out[conn.out_pos..]) {
                    Ok(0) => {
                        failed = true;
                        break;
                    }
                    Ok(n) => conn.out_pos += n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        failed = true;
                        break;
                    }
                }
            }
            if failed {
                After::Close
            } else if conn.out_pos >= conn.out.len() {
                conn.out.clear();
                conn.out_pos = 0;
                if conn.closing {
                    After::Close
                } else if conn.want_write {
                    conn.want_write = false;
                    After::DisarmWrite
                } else {
                    After::Nothing
                }
            } else if !conn.want_write {
                conn.want_write = true;
                After::ArmWrite
            } else {
                After::Nothing
            }
        };
        match after {
            After::Close => {
                self.close_conn(slot);
                false
            }
            After::ArmWrite => {
                if let Some(fd) = self.conn_fd(slot) {
                    self.poller.modify(fd, slot as u64, EPOLLIN | EPOLLOUT);
                }
                true
            }
            After::DisarmWrite => {
                if let Some(fd) = self.conn_fd(slot) {
                    self.poller.modify(fd, slot as u64, EPOLLIN);
                }
                true
            }
            After::Nothing => true,
        }
    }

    fn conn_fd(&self, slot: usize) -> Option<i32> {
        self.conns[slot].as_ref().map(|c| fd_of(&c.stream))
    }

    /// Slow-client protection: past the high-water mark the connection
    /// is cut, with a best-effort structured `slow-consumer` error
    /// replacing whatever it was not reading. Returns false when the
    /// connection was closed.
    fn enforce_outbound_cap(&mut self, slot: usize) -> bool {
        let limit = self.cfg.max_outbound;
        let queued = match self.conns[slot].as_ref() {
            Some(c) => c.queued(),
            None => return false,
        };
        if queued <= limit {
            return true;
        }
        self.scratch.clear();
        wire::encode_response_tagged_into(
            &Response::Error(ApiError::SlowConsumer { queued, limit }),
            None,
            &mut self.scratch,
        );
        self.scratch.push('\n');
        if let Some(conn) = self.conns[slot].as_mut() {
            conn.out.clear();
            conn.out_pos = 0;
            // Best-effort: whatever one nonblocking write delivers.
            let _ = conn.stream.write(self.scratch.as_bytes());
        }
        if let Some(m) = self.serving() {
            m.slow_client_disconnects.inc();
        }
        self.close_conn(slot);
        false
    }

    // -- teardown ----------------------------------------------------------

    fn close_conn(&mut self, slot: usize) {
        let Some(conn) = self.conns[slot].take() else {
            return;
        };
        self.poller.del(fd_of(&conn.stream));
        // Undelivered pending replies: tickets stay in the table, so a
        // reconnecting client can still redeem them; their eventual
        // notices are dropped by the generation check.
        self.free.push(slot);
        self.open -= 1;
        if let Some(m) = self.serving() {
            m.open_connections.set(self.open);
        }
        // conn (and its stream) drop here.
    }
}

fn fd_of<T: std::os::unix::io::AsRawFd>(t: &T) -> i32 {
    t.as_raw_fd()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoll_event_is_kernel_packed() {
        // x86-64 kernel ABI: 12 bytes, not 16.
        assert_eq!(std::mem::size_of::<EpollEvent>(), 12);
    }

    #[test]
    fn conn_tokens_roundtrip_slot_and_generation() {
        for (slot, gen) in [(0usize, 1u32), (7, 42), (65_535, u32::MAX)] {
            let t = conn_token(slot, gen);
            assert_eq!(token_slot(t), slot);
            assert_eq!(token_gen(t), gen);
        }
        assert_ne!(conn_token(3, 1), conn_token(3, 2), "reuse changes the token");
    }

    #[test]
    fn deadline_heap_pops_earliest_first() {
        let mut h = BinaryHeap::new();
        let now = Instant::now();
        let late = now + Duration::from_secs(2);
        let soon = now + Duration::from_millis(1);
        h.push(DeadlineAt(std::cmp::Reverse((late, 1, 1))));
        h.push(DeadlineAt(std::cmp::Reverse((soon, 2, 2))));
        let DeadlineAt(std::cmp::Reverse((at, token, _))) = h.pop().unwrap();
        assert_eq!(at, soon);
        assert_eq!(token, 2);
    }

    #[test]
    fn nofile_limit_raise_is_monotone_best_effort() {
        let cur = raise_nofile_limit(1);
        assert!(cur >= 1);
        let after = raise_nofile_limit(cur);
        assert!(after >= cur);
    }

    #[test]
    fn poller_and_bus_construct_and_wake() {
        let p = Poller::new().unwrap();
        let bus = CompletionBus::new().unwrap();
        p.add(bus.wake_fd, TOKEN_WAKE, EPOLLIN).unwrap();
        // Without a kick: times out, no events.
        let mut evs = [EpollEvent { events: 0, data: 0 }; 4];
        assert_eq!(p.wait(&mut evs, Duration::from_millis(1)), 0);
        // A completion kick makes the eventfd readable.
        bus.complete(conn_token(0, 1), 0, Ticket(1), Err(ApiError::ShuttingDown));
        let n = p.wait(&mut evs, Duration::from_millis(100));
        assert_eq!(n, 1);
        assert_eq!(evs[0].data, TOKEN_WAKE);
        bus.drain_wake();
        let notices = bus.take();
        assert_eq!(notices.len(), 1);
        assert_eq!(notices[0].ticket, Ticket(1));
        assert_eq!(token_gen(notices[0].conn), 1);
    }
}
