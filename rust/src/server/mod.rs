//! Real-time serving: a wall-clock driver around [`ControlPlane`] plus a
//! TCP line-protocol front end.
//!
//! Python never runs here — dispatched functions execute their AOT HLO
//! artifact on a dedicated PJRT executor thread (the CPU PJRT client is
//! the testbed's stand-in for the GPU; see DESIGN.md §1). Modeled
//! control-plane delays (cold boots, prefetch blocking) are slept at a
//! configurable time scale so demos finish quickly.
//!
//! Protocol (one line per request):
//! ```text
//! > invoke <registered-fn-name>
//! < ok <latency_ms> <exec_ms> <start-kind> <gpu>
//! > stats
//! < ok invocations=<n> mean_latency_ms=<x> cold_ratio=<r>
//! > quit
//! ```

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::clock::{Clock, RealClock};
use crate::plane::{ControlPlane, Dispatch, PlaneConfig};
use crate::runtime::PjrtRuntime;
use crate::types::{to_secs, FuncId, InvocationId, Nanos, StartKind};
use crate::workload::Workload;

/// Completion notification delivered to the submitter.
#[derive(Debug, Clone)]
pub struct Completion {
    pub inv: InvocationId,
    pub func: FuncId,
    pub latency: Duration,
    pub exec: Duration,
    pub start_kind: StartKind,
    pub gpu: u32,
}

/// Job sent to the PJRT executor thread.
struct ExecJob {
    artifact: String,
    reply: Sender<Duration>,
}

struct Inner {
    plane: Mutex<ControlPlane>,
    clock: RealClock,
    /// Modeled-delay scale: 1 virtual second sleeps `scale` real seconds.
    scale: f64,
    exec_tx: Option<Sender<ExecJob>>,
    waiters: Mutex<HashMap<InvocationId, Sender<Completion>>>,
    running: AtomicBool,
}

/// The real-time driver. Construct with [`RtServer::new`], submit with
/// [`RtServer::submit`], optionally serve TCP with [`RtServer::serve`].
pub struct RtServer {
    inner: Arc<Inner>,
    monitor: Option<thread::JoinHandle<()>>,
}

impl RtServer {
    /// `artifacts_dir`: load + compile HLO artifacts and execute them on
    /// dispatch (real execution). `None`: sleep the modeled service time
    /// instead (pure control-plane demo).
    pub fn new(
        workload: Workload,
        cfg: PlaneConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
    ) -> anyhow::Result<Self> {
        assert!(scale > 0.0);
        let exec_tx = match artifacts_dir {
            Some(dir) => Some(Self::spawn_executor(dir, &workload)?),
            None => None,
        };
        let monitor_period = cfg.monitor_period;
        let inner = Arc::new(Inner {
            plane: Mutex::new(ControlPlane::new(workload, cfg)),
            clock: RealClock::new(),
            scale,
            exec_tx,
            waiters: Mutex::new(HashMap::new()),
            running: AtomicBool::new(true),
        });
        // Monitor thread: scaled 200 ms ticks.
        let mon_inner = Arc::clone(&inner);
        let monitor = thread::spawn(move || {
            let period = Duration::from_nanos((monitor_period as f64) as u64);
            while mon_inner.running.load(Ordering::SeqCst) {
                thread::sleep(period);
                let now = mon_inner.clock.now();
                let ds = mon_inner.plane.lock().unwrap().on_monitor_tick(now);
                handle_dispatches(&mon_inner, ds);
            }
        });
        Ok(Self {
            inner,
            monitor: Some(monitor),
        })
    }

    /// PJRT executor thread: owns the (non-Send) runtime; executes one
    /// artifact at a time. The serialization is harmless — the CPU PJRT
    /// client is itself internally parallel and stands in for one GPU.
    fn spawn_executor(
        dir: &std::path::Path,
        workload: &Workload,
    ) -> anyhow::Result<Sender<ExecJob>> {
        let (tx, rx): (Sender<ExecJob>, Receiver<ExecJob>) = channel();
        let dir = dir.to_path_buf();
        let names: Vec<String> = {
            let mut v: Vec<String> = workload
                .funcs
                .iter()
                .map(|f| f.class.name.to_string())
                .collect();
            v.sort();
            v.dedup();
            v
        };
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        thread::spawn(move || {
            let mut rt = match PjrtRuntime::new(&dir) {
                Ok(rt) => rt,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            for name in &names {
                if let Err(e) = rt.load_function(name) {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            }
            let _ = ready_tx.send(Ok(()));
            while let Ok(job) = rx.recv() {
                let t0 = std::time::Instant::now();
                let _ = rt.execute(&job.artifact);
                let _ = job.reply.send(t0.elapsed());
            }
        });
        ready_rx.recv().expect("executor thread died")?;
        Ok(tx)
    }

    /// Submit one invocation; returns a receiver for its completion.
    pub fn submit(&self, func: FuncId) -> Receiver<Completion> {
        let (tx, rx) = channel();
        let now = self.inner.clock.now();
        let ds = {
            let mut plane = self.inner.plane.lock().unwrap();
            let (id, ds) = plane.on_arrival(func, now);
            self.inner.waiters.lock().unwrap().insert(id, tx);
            ds
        };
        handle_dispatches(&self.inner, ds);
        rx
    }

    /// Resolve a registered function by name.
    pub fn func_by_name(&self, name: &str) -> Option<FuncId> {
        let plane = self.inner.plane.lock().unwrap();
        plane
            .workload()
            .funcs
            .iter()
            .find(|f| f.name == name || f.class.name == name)
            .map(|f| f.id)
    }

    /// Snapshot of recorder stats: (completed, mean latency s, cold ratio).
    pub fn stats(&self) -> (usize, f64, f64) {
        let plane = self.inner.plane.lock().unwrap();
        (
            plane.recorder.len(),
            plane.recorder.weighted_avg_latency_s(),
            plane.recorder.cold_ratio(),
        )
    }

    /// Serve the line protocol on `addr` until `quit` or shutdown.
    /// Returns the bound address (use port 0 to pick a free one).
    pub fn serve(&self, addr: &str) -> anyhow::Result<std::net::SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let inner = Arc::clone(&self.inner);
        let me = RtServer {
            inner: Arc::clone(&self.inner),
            monitor: None,
        };
        thread::spawn(move || {
            for stream in listener.incoming() {
                if !inner.running.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let server = RtServer {
                    inner: Arc::clone(&me.inner),
                    monitor: None,
                };
                thread::spawn(move || server.handle_conn(stream));
            }
        });
        Ok(local)
    }

    fn handle_conn(&self, stream: TcpStream) {
        let peer = stream.peer_addr().ok();
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let Ok(line) = line else { break };
            let mut parts = line.trim().split_whitespace();
            let reply = match parts.next() {
                Some("invoke") => match parts.next().and_then(|n| self.func_by_name(n)) {
                    Some(func) => match self.submit(func).recv() {
                        Ok(c) => format!(
                            "ok {:.1} {:.1} {} gpu{}",
                            c.latency.as_secs_f64() * 1e3,
                            c.exec.as_secs_f64() * 1e3,
                            c.start_kind,
                            c.gpu
                        ),
                        Err(_) => "err completion channel closed".to_string(),
                    },
                    None => "err unknown function".to_string(),
                },
                Some("stats") => {
                    let (n, lat, cold) = self.stats();
                    format!(
                        "ok invocations={n} mean_latency_ms={:.1} cold_ratio={:.3}",
                        lat * 1e3,
                        cold
                    )
                }
                Some("quit") | None => break,
                Some(other) => format!("err unknown command {other}"),
            };
            if writer.write_all((reply + "\n").as_bytes()).is_err() {
                break;
            }
        }
        let _ = peer;
    }

    pub fn shutdown(&mut self) {
        self.inner.running.store(false, Ordering::SeqCst);
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RtServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Run each dispatch on a worker thread: sleep the scaled pre-exec
/// delays, execute (PJRT or modeled sleep), then complete.
fn handle_dispatches(inner: &Arc<Inner>, ds: Vec<Dispatch>) {
    for d in ds {
        let inner = Arc::clone(inner);
        thread::spawn(move || run_dispatch(&inner, d));
    }
}

fn run_dispatch(inner: &Arc<Inner>, d: Dispatch) {
    let scale = inner.scale;
    let sleep_scaled = |ns: Nanos| {
        if ns > 0 {
            thread::sleep(Duration::from_secs_f64(to_secs(ns) * scale));
        }
    };
    // Cold boot + shim blocking (modeled, scaled).
    sleep_scaled(d.exec_start.saturating_sub(d.at));
    let exec_t0 = inner.clock.now();

    // Service: real PJRT execution, or the modeled time scaled.
    let class_name = {
        let plane = inner.plane.lock().unwrap();
        plane.workload().func(d.func).class.name.to_string()
    };
    let measured = match &inner.exec_tx {
        Some(tx) => {
            let (rtx, rrx) = channel();
            if tx
                .send(ExecJob {
                    artifact: class_name,
                    reply: rtx,
                })
                .is_ok()
            {
                rrx.recv().unwrap_or_default()
            } else {
                Duration::ZERO
            }
        }
        None => {
            sleep_scaled(d.exec);
            Duration::ZERO
        }
    };
    let _ = measured;

    let now = inner.clock.now();
    let (ds, completion) = {
        let mut plane = inner.plane.lock().unwrap();
        let ds = plane.on_complete(d.inv, now);
        let rec = plane.recorder.records.last().copied();
        (ds, rec)
    };
    if let Some(rec) = completion {
        if rec.inv == d.inv {
            if let Some(tx) = inner.waiters.lock().unwrap().remove(&d.inv) {
                let _ = tx.send(Completion {
                    inv: d.inv,
                    func: d.func,
                    latency: Duration::from_nanos(rec.completed - rec.arrived),
                    exec: Duration::from_nanos(now.saturating_sub(exec_t0)),
                    start_kind: d.start_kind,
                    gpu: d.gpu.0,
                });
            }
        }
    }
    handle_dispatches(inner, ds);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::catalog::by_name;

    fn workload() -> Workload {
        let mut w = Workload::default();
        w.register(by_name("isoneural").unwrap(), 0, 1.0);
        w.register(by_name("fft").unwrap(), 0, 1.0);
        w
    }

    fn fast_cfg() -> PlaneConfig {
        PlaneConfig {
            monitor_period: 20 * crate::types::MS,
            ..Default::default()
        }
    }

    #[test]
    fn submit_completes_in_model_mode() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let c = srv
            .submit(FuncId(0))
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        assert_eq!(c.func, FuncId(0));
        assert_eq!(c.start_kind, StartKind::Cold);
        assert!(c.latency > Duration::ZERO);
        let (n, lat, cold) = srv.stats();
        assert_eq!(n, 1);
        assert!(lat > 0.0);
        assert!((cold - 1.0).abs() < 1e-9);
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.0005).unwrap();
        let rxs: Vec<_> = (0..6)
            .map(|i| srv.submit(FuncId((i % 2) as u32)))
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        assert_eq!(srv.stats().0, 6);
    }

    #[test]
    fn tcp_roundtrip() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.0005).unwrap();
        let addr = srv.serve("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"invoke isoneural-0\nstats\nquit\n").unwrap();
        let mut lines = BufReader::new(conn.try_clone().unwrap()).lines();
        let first = lines.next().unwrap().unwrap();
        assert!(first.starts_with("ok "), "{first}");
        let second = lines.next().unwrap().unwrap();
        assert!(second.contains("invocations=1"), "{second}");
    }

    #[test]
    fn unknown_function_rejected() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let addr = srv.serve("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"invoke ghost\nquit\n").unwrap();
        let mut lines = BufReader::new(conn).lines();
        let first = lines.next().unwrap().unwrap();
        assert!(first.starts_with("err"), "{first}");
    }
}
