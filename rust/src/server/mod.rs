//! Real-traffic serving: wall-clock frontends around the control plane,
//! speaking protocol v1 ([`crate::api`]) over TCP.
//!
//! Two [`Frontend`] implementations share one engine:
//!
//! * [`RtServer`] — a single [`ControlPlane`] (the original per-server
//!   driver, now behind the typed API).
//! * [`RtCluster`] — N independent [`ControlPlane`] shards behind a
//!   [`crate::cluster::Router`] (StickyCh / least-loaded / ...), the
//!   wall-clock sibling of [`crate::sim::replay_cluster`].
//!
//! Python never runs here — dispatched functions execute their AOT HLO
//! artifact on a dedicated PJRT executor thread (the CPU PJRT client is
//! the testbed's stand-in for the GPU; see DESIGN.md §1). Modeled
//! control-plane delays (cold boots, prefetch blocking) are slept at a
//! configurable time scale so demos finish quickly.
//!
//! # Protocol
//!
//! One JSON document per line, both directions, after a `hello`
//! version handshake (see [`crate::api::wire`] for the full grammar):
//!
//! ```text
//! > {"cmd":"hello","v":1}
//! < {"ok":true,"type":"hello","proto":1,"server":"rt-cluster"}
//! > {"cmd":"invoke","func":"fft-0","mode":"sync","deadline_ms":5000}
//! < {"ok":true,"type":"done","ticket":0,"func":"fft-0","shard":1,
//!    "gpu":0,"start":"cold","latency_ms":412.0,"exec_ms":9.1}
//! > {"cmd":"invoke","func":"fft-0","mode":"async"}
//! < {"ok":true,"type":"ticket","ticket":1}
//! > {"cmd":"wait","ticket":1}
//! < {"ok":true,"type":"done", ...}
//! > {"cmd":"stats"}
//! < {"ok":true,"type":"stats","invocations":2, ...}
//! ```
//!
//! Errors are structured (`{"ok":false,"error":"unknown-function",...}`;
//! taxonomy in [`crate::api::ApiError`]). The pre-v1 word protocol —
//! `invoke <fn>` / `stats` / `quit` with `ok ...`/`err ...` replies —
//! survives as legacy aliases on the same port: any line not starting
//! with `{` is parsed as a legacy command.
//!
//! On the event loop a connection may also *pipeline*: send many
//! requests without reading replies, tag each with `"id":N`, and match
//! the echoed `"id"` on possibly out-of-order replies. Async invokes
//! can subscribe at submit (`"push":true`) and receive an unsolicited
//! `{"ok":true,"type":"push",...}` completion instead of polling.
//! Untagged requests get byte-identical replies to the old lockstep
//! loop (pinned by test), so legacy clients never notice the loop.
//!
//! # Threading model: fixed pools, a timer wheel, and no per-request spawns
//!
//! The serving engine's thread count is a function of *configuration*,
//! never of offered load:
//!
//! * **One timer thread** owns a binary-heap timer wheel of pending
//!   wall-clock events — each dispatch's `exec_start` instant (cold
//!   boot + prefetch blocking, scaled) and, in model mode, its
//!   completion instant. When an event comes due the timer hands it to
//!   the owning shard's worker pool and goes back to sleep until the
//!   next deadline; it never touches a plane lock itself.
//! * **A fixed worker pool per shard** ([`DEFAULT_WORKERS`] threads
//!   unless overridden via `with_workers`) drains the shard's work
//!   queue: exec-start touches, PJRT execution (workers block on the
//!   executor, bounding concurrent jobs), completion bookkeeping, and
//!   ticket fulfillment. Model-mode workers never sleep — modeled
//!   service time is a timer event, so a worker's cost per invocation
//!   is bookkeeping only.
//! * **One monitor thread per shard** drives the paper's 200 ms-class
//!   NVML poll (utilization sampling, dynamic D, TTL expiry). Idle
//!   shards park on a condvar instead of ticking: the monitor only
//!   sleeps-and-locks while the shard has work, and a submit to an
//!   idle shard wakes it. An idle server generates *zero* tick-driven
//!   plane-lock traffic (asserted by test via [`RtServer::monitor_ticks`]).
//! * **One event-loop (poller) thread per listening address** speaks
//!   the wire protocol for *every* connection ([`event_loop`]): an
//!   epoll readiness loop owns the listener, all connection sockets,
//!   and their per-connection reuse buffers. Accepts, reads, parses,
//!   and nonblocking batched flushes all run on this one thread —
//!   there is no accept thread and no thread per connection, so 10k
//!   open connections cost the same thread count as one.
//!
//! The split of work between the poller thread and the executor side:
//!
//! * **Poller thread** (per `serve` call): accept, read, parse
//!   (borrowed [`crate::api::wire::JVal`]), submit (which may take one
//!   plane lock), encode, flush, and the pending-reply bookkeeping
//!   (reply tags, wait deadlines, push subscriptions). It never blocks
//!   on a ticket: sync invokes and waits are parked as pending replies
//!   and answered when the completion arrives.
//! * **Worker/timer threads**: execution and completion bookkeeping,
//!   exactly as below. At ticket-resolution time a completion crosses
//!   back to the poller via the [`event_loop::CompletionBus`] (mutex
//!   push + eventfd wake) — executors never touch a socket.
//!
//! The previous designs spawned a fresh OS thread per dispatch (and,
//! until this revision, one per connection), so thread count — and
//! scheduler pressure — grew with load; [`RtServer::exec_threads`]
//! exposes the (constant) executor-side count so tests can pin the
//! invariant under a burst, and total serving threads stay
//! `shards × workers + O(1)`.
//!
//! # Lock discipline on the submit path
//!
//! A submit on an M-shard cluster locks at most one [`ControlPlane`]
//! — the routed shard's:
//!
//! * Shard load snapshots ([`crate::cluster::ShardLoad`]) read per-shard
//!   atomics published under the plane lock at every mutation, so
//!   admission control and routing never lock any plane.
//! * The router sits behind a read-mostly `RwLock` and
//!   [`crate::cluster::Router::route`] takes `&self` (StickyCh's ring
//!   is immutable after build; RoundRobin keeps an atomic cursor), so
//!   concurrent submits route in parallel.
//! * The ticket registry is sharded by ticket id ([`TICKET_SHARDS`]
//!   slots), and invocation→ticket maps are per plane-shard, so
//!   concurrent clients don't serialize on one mutex.
//! * `stats` is O(shards) over atomics — the aggregate counters
//!   (completions, latency sum, cold starts) are maintained at
//!   completion time, and no plane is ever locked to answer it.
//!
//! # Elastic membership and the epoch rule
//!
//! The shard set is elastic at runtime: the admin verbs `drain` /
//! `join` / `kill` / `membership` (wire commands, [`crate::api::Frontend`]
//! methods) flip per-shard [`crate::api::ShardHealth`] in place — shard
//! *indices* are stable for the life of the server. Health changes and
//! ring healing happen behind the read-mostly router lock's *write*
//! side (membership is rare; submits keep routing in parallel through
//! the read side):
//!
//! * **drain** — the shard's [`ShardLoad::routable`] flag drops and its
//!   consistent-hash vnodes leave the ring; queued/in-flight work runs
//!   to completion on the draining plane, then the shard idles.
//! * **join** — the shard becomes routable again, reinserting exactly
//!   its original vnodes (functions homed elsewhere keep their homes).
//!   After a kill it comes back cold and rebuilds warm locality — the
//!   elastic harness (`experiments/elastic.rs`) measures that recovery
//!   curve.
//! * **kill** — abrupt failure. Under the shard's plane lock: the plane
//!   is replaced with a cold rebuild, the shard **epoch** is bumped,
//!   and every invocation→ticket mapping is drained; each stranded
//!   ticket then resolves to [`ApiError::ShardLost`] — waiters blocked
//!   in `wait` wake *immediately* with the structured error, they never
//!   hang until their deadline.
//!
//! The epoch is the replay-safety rule: a rebuilt plane restarts
//! invocation ids at 0, so a timer event scheduled before the kill
//! (an exec-start or modeled completion) could otherwise be delivered
//! to an unrelated new invocation with a recycled id. Every
//! [`WorkItem`] is stamped with its shard's epoch at schedule time
//! (read under the plane lock) and re-checked under the plane lock at
//! delivery; mismatches are counted (`stale_drops`) and dropped.
//!
//! **Ticket-fate conservation.** Every admitted submission gets exactly
//! one fate — completed, failed ([`ApiError::ShardLost`]), or it is
//! still outstanding; rejected submissions (overload, unknown function,
//! shutdown) never enter the count. The `membership` snapshot exposes
//! the counters (`accepted`, `completed`, `failed`, `rejected`,
//! `stale_drops`) and [`crate::api::MembershipInfo::conserved_at_quiescence`]
//! checks the invariant; the elastic harness gates on it after a
//! kill storm. The kill path keeps it exact by draining the
//! invocation→ticket map under the same plane lock the completion path
//! uses to claim a mapping — a racing completion either claims the
//! ticket before the kill (counted `completed`) or finds its epoch
//! stale after it (counted `stale_drops`, ticket already `failed`).
//!
//! The last live shard can be neither drained nor killed: a frontend
//! with no routable shard would turn every submit into an error with no
//! in-band recovery path.
//!
//! # Failure model
//!
//! Shard-level failure (`kill`) is handled above; *device-level* and
//! *invocation-level* failure ride in from the plane layer when a
//! [`crate::fault::FaultConfig`] is installed
//! ([`crate::plane::PlaneConfig::faults`]). The serving layer adds no
//! fault logic of its own — it maps the plane's decisions onto tickets
//! and admission answers, preserving exactly-once ticket fates:
//!
//! * **Admission** — `submit` consults [`ControlPlane::try_admit`]
//!   under the same plane lock as the arrival. A function whose
//!   circuit breaker is Open is refused with
//!   [`ApiError::Quarantined`] (structured `retry_after_ms` = the
//!   remaining cooldown); deadline-aware shedding refuses with
//!   [`ApiError::Overloaded`] carrying the configured backoff hint.
//!   Both count as `rejected` — the provisional ticket is retracted
//!   and nothing enters the fate ledger.
//! * **Transient faults and stragglers** — a faulted attempt is
//!   re-queued *inside* the plane; the invocation→ticket mapping is
//!   claimed only when a completion carries a record, so the retry's
//!   completion (a later attempt number) fulfills the original ticket.
//!   Superseded completions — a stale timer item for an attempt the
//!   watchdog already evacuated — return no record and touch nothing.
//! * **Retry exhaustion** — the plane emits a [`FaultFate`] when an
//!   invocation burns its whole retry budget. Fates are claimed under
//!   the plane lock (same exactness rule as completion vs. kill) and
//!   resolved to [`ApiError::ExecFailed`] with the attempt count;
//!   blocked waiters wake immediately, `failed` is counted, and fate
//!   conservation (`accepted == completed + failed + outstanding`)
//!   still holds — an invocation is never both failed and completed.
//!
//! With no fault plan every hook above is a no-op and the dispatch
//! stream is bit-identical to a server without this layer (the
//! equivalence is property-tested at the plane and sim layers).
//!
//! # Observability
//!
//! Every frontend owns one [`crate::telemetry::Telemetry`] instance,
//! attached to each shard's [`ControlPlane`] at construction (and
//! re-attached to the cold plane a `kill` rebuilds). The planes emit
//! the full invocation lifecycle — the *same* event vocabulary the
//! simulator emits, because the emission sites live in the shared
//! plane layer — while this module adds the serving-only events:
//! `route` at submit time (payload: shard epoch + spill flag) and
//! `epoch`/`error` when a kill rebuilds a plane and strands tickets.
//!
//! Two wire verbs export the subsystem live, with no new locks on the
//! serving path:
//!
//! * `metrics` — the whole registry rendered as Prometheus text or a
//!   JSON document (reads are `Relaxed` atomic loads; rendering
//!   allocates only in the request handler).
//! * `trace` — drains up to `max` events from the bounded ring
//!   (oldest-first), plus the cumulative overflow-drop counter.
//!
//! The per-shard `stats` breakdown (pending, in-flight, completions,
//! cold ratio, health, epoch) reads only the published per-shard
//! atomics — the same ones routing uses — so `stats` stays O(shards)
//! with zero plane locks.
//!
//! # Ownership: handles vs the shutdown guard
//!
//! All serving state lives in one shared `Inner`. [`RtHandle`] is a
//! cloneable `Arc` view of it — the event loop and embedders hold
//! handles, and dropping a handle is inert. The constructor-returned
//! guard ([`RtServer`]/[`RtCluster`]) is the *single* owner of
//! shutdown: only its `shutdown()`/`Drop` stops the background
//! threads (timer, workers, monitors) and the event loop.
//! Stopping the guard abandons modeled in-flight work still parked on
//! the timer (their waiters see a deadline/unknown-ticket, exactly as
//! under process teardown); in-flight PJRT executions finish their
//! current job. (The historical drop bug — per-connection guard clones
//! running `Drop::drop → shutdown()` on first disconnect — is still
//! pinned by a regression test in `rust/tests/wire_protocol.rs`.)

pub mod event_loop;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::types::{
    ApiError, DescribeInfo, InvokeOutcome, MembershipInfo, MetricsFormat, ShardHealth, ShardInfo,
    ShardStatsRow, StatsSnapshot, Ticket, PROTOCOL_VERSION,
};
use crate::api::{CompletionSink, Frontend};
use crate::clock::{Clock, RealClock};
use crate::cluster::{ClusterConfig, Router, RouterKind, ShardLoad};
use crate::fault::{AdmitError, FaultFate};
use crate::plane::{ControlPlane, Dispatch, PlaneConfig};
use crate::runtime::PjrtRuntime;
use crate::telemetry::{self, EventKind, Telemetry, TraceEvent};
use crate::types::{to_secs, FuncId, InvocationId, Nanos, StartKind};
use crate::workload::Workload;

/// Worker threads per shard unless overridden (`with_workers`). Total
/// executor-side threads = `shards × workers + 1` (the timer).
pub const DEFAULT_WORKERS: usize = 4;

/// Ticket-registry shards: tickets hash to a slot by id, so concurrent
/// clients touching different tickets never contend on one mutex.
pub const TICKET_SHARDS: usize = 16;

/// Job sent to the PJRT executor thread.
struct ExecJob {
    artifact: &'static str,
    reply: Sender<Duration>,
}

/// One registered consumer of a pending ticket's resolution: a blocked
/// `wait` call's wake channel, or a push subscription delivering to an
/// event loop's [`CompletionSink`] (no thread blocks anywhere on the
/// push path).
enum Waiter {
    Chan(Sender<Result<InvokeOutcome, ApiError>>),
    Push {
        sink: Arc<dyn CompletionSink>,
        /// Opaque subscriber routing words, echoed back verbatim (the
        /// event loop packs a generation-stamped connection token and a
        /// per-connection reply tag).
        conn: u64,
        tag: u64,
    },
}

impl Waiter {
    /// Deliver the ticket's resolution to this waiter.
    fn notify(self, ticket: Ticket, result: Result<InvokeOutcome, ApiError>) {
        match self {
            Waiter::Chan(tx) => {
                let _ = tx.send(result);
            }
            Waiter::Push { sink, conn, tag } => sink.complete(conn, tag, ticket, result),
        }
    }
}

/// Completion bookkeeping for one accepted invocation.
enum TicketEntry {
    /// Still running; waiters are woken (all of them) on completion —
    /// with the outcome, or with the structured error that became the
    /// ticket's fate (e.g. [`ApiError::ShardLost`] after a kill).
    Pending { waiters: Vec<Waiter> },
    /// Completed but not yet claimed by `wait`/`poll`.
    Done(InvokeOutcome),
    /// Terminally failed (shard lost) but not yet claimed; the next
    /// `wait`/`poll` claims the structured error exactly like a `Done`
    /// outcome.
    Failed(ApiError),
}

impl TicketEntry {
    /// Terminal (unclaimed-completion) entries, counted against the
    /// table's `max_done` bound.
    fn is_terminal(&self) -> bool {
        matches!(self, TicketEntry::Done(_) | TicketEntry::Failed(_))
    }
}

/// Ticket registry slot with a bound on completed-but-unclaimed
/// entries, so fire-and-forget async clients (or crashed ones) cannot
/// grow the table without limit on a long-running server: beyond the
/// slot's `max_done` unclaimed completions, the oldest are evicted (a
/// later `wait` on one gets `unknown-ticket`, exactly as if it had
/// been claimed). The server keeps [`TICKET_SHARDS`] slots whose
/// bounds sum to [`TicketTable::DEFAULT_MAX_DONE`].
struct TicketTable {
    entries: HashMap<u64, TicketEntry>,
    /// Completion order of terminal (`Done`/`Failed`) entries; may
    /// contain stale ids of since-claimed tickets (filtered during
    /// eviction — ids are never reused, so staleness is unambiguous).
    done_order: VecDeque<u64>,
    /// Live terminal entries (kept ≤ `max_done`).
    done_count: usize,
    max_done: usize,
    /// Ids whose unclaimed completion was evicted by the `max_done`
    /// bound, so a late `wait` can be told `unknown-ticket` *with the
    /// evicted hint* instead of looking like a typo. Bounded like the
    /// table itself (oldest forgotten first — a very late waiter
    /// degrades to the plain unknown-ticket answer).
    evicted: VecDeque<u64>,
}

impl TicketTable {
    /// Unclaimed completions retained across all slots before the
    /// oldest are dropped.
    const DEFAULT_MAX_DONE: usize = 1 << 16;

    fn with_max(max_done: usize) -> Self {
        Self {
            entries: HashMap::new(),
            done_order: VecDeque::new(),
            done_count: 0,
            max_done,
            evicted: VecDeque::new(),
        }
    }

    fn insert_pending(&mut self, id: u64) {
        self.entries.insert(
            id,
            TicketEntry::Pending {
                waiters: Vec::new(),
            },
        );
    }

    /// Remove an entry, keeping the unclaimed-terminal count in sync.
    fn remove(&mut self, id: u64) -> Option<TicketEntry> {
        let entry = self.entries.remove(&id);
        if entry.as_ref().is_some_and(TicketEntry::is_terminal) {
            self.done_count -= 1;
        }
        entry
    }

    /// Was `id`'s completed-but-unclaimed entry evicted by the bound?
    fn was_evicted(&self, id: u64) -> bool {
        self.evicted.contains(&id)
    }

    /// Make `id` terminal, returning the displaced entry (the waiters
    /// to wake). Evicts the oldest unclaimed terminals over the bound.
    fn resolve(&mut self, id: u64, entry: TicketEntry) -> Option<TicketEntry> {
        debug_assert!(entry.is_terminal());
        let prev = self.entries.insert(id, entry);
        if !prev.as_ref().is_some_and(TicketEntry::is_terminal) {
            self.done_count += 1;
        }
        self.done_order.push_back(id);
        while self.done_count > self.max_done {
            let Some(old) = self.done_order.pop_front() else {
                break;
            };
            if self.entries.get(&old).is_some_and(TicketEntry::is_terminal) {
                self.entries.remove(&old);
                self.done_count -= 1;
                self.evicted.push_back(old);
                // Keep the eviction memory bounded too.
                while self.evicted.len() > self.max_done.max(64) {
                    self.evicted.pop_front();
                }
            }
        }
        // The order queue accumulates stale ids of promptly-claimed
        // tickets; compact it once it doubles past the live bound
        // (amortized O(1) per completion, keeps both structures bounded).
        if self.done_order.len() > self.max_done.saturating_mul(2).max(64) {
            let entries = &self.entries;
            self.done_order
                .retain(|id| entries.get(id).is_some_and(TicketEntry::is_terminal));
        }
        prev
    }

    /// Mark `id` done (successful completion).
    fn complete(&mut self, id: u64, outcome: InvokeOutcome) -> Option<TicketEntry> {
        self.resolve(id, TicketEntry::Done(outcome))
    }

    /// Mark `id` terminally failed (e.g. its shard was killed).
    fn fail(&mut self, id: u64, err: ApiError) -> Option<TicketEntry> {
        self.resolve(id, TicketEntry::Failed(err))
    }
}

/// Work handed to a shard's worker pool by the timer thread. Every
/// item carries the shard epoch it was scheduled under (read beneath
/// the plane lock); delivery re-checks it beneath the same lock and
/// drops mismatches — a rebuilt plane restarts invocation ids, so a
/// stale item could otherwise touch an unrelated new invocation.
enum WorkItem {
    /// The dispatch's scaled pre-exec delay (boot + blocking) elapsed:
    /// touch the plane at the wall-clock exec start, then execute
    /// (PJRT inline, or schedule the modeled completion on the timer).
    ExecStart { d: Dispatch, epoch: u64 },
    /// The modeled service time elapsed (model mode only): complete
    /// the invocation and fulfill its ticket.
    Complete {
        d: Dispatch,
        exec_t0: Nanos,
        epoch: u64,
    },
}

/// One timer-wheel entry; ordered by `(due, seq)` so same-instant
/// events fire in schedule order.
struct TimerEntry {
    due: Instant,
    seq: u64,
    shard: usize,
    item: WorkItem,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.due, self.seq).cmp(&(other.due, other.seq))
    }
}

/// Binary-heap timer wheel: one thread sleeps until the earliest
/// deadline and hands due events to shard worker queues. Scheduling is
/// lock + push + notify; O(log n) in outstanding events.
struct Timer {
    heap: Mutex<BinaryHeap<Reverse<TimerEntry>>>,
    cv: Condvar,
    seq: AtomicU64,
}

impl Timer {
    fn new() -> Self {
        Self {
            heap: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            seq: AtomicU64::new(0),
        }
    }

    fn schedule(&self, due: Instant, shard: usize, item: WorkItem) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.heap
            .lock()
            .unwrap()
            .push(Reverse(TimerEntry {
                due,
                seq,
                shard,
                item,
            }));
        self.cv.notify_one();
    }
}

/// Per-shard serving state: the plane, its published load snapshot,
/// the worker inbox, and the monitor's park gate.
struct ShardState {
    plane: Mutex<ControlPlane>,
    /// Load snapshot published under the plane lock at every mutation;
    /// admission control, routing, and `stats` read these without ever
    /// locking the plane.
    pending: AtomicUsize,
    in_flight: AtomicUsize,
    /// Fleet capacity (V100-equivalents) for [`ShardLoad`].
    capacity: f64,
    /// Worker-pool inbox, fed by the timer thread.
    work: Mutex<VecDeque<WorkItem>>,
    work_cv: Condvar,
    /// Monitor park gate: true ⇒ a submit woke an idle shard.
    gate: Mutex<bool>,
    gate_cv: Condvar,
    /// Monitor ticks that actually locked the plane (diagnostics; an
    /// idle shard's count must not grow).
    ticks: AtomicU64,
    /// shard-local invocation id → ticket, registered under the plane
    /// lock at submit time so a racing completion can never observe an
    /// unmapped invocation. The kill path drains it under the plane
    /// lock too — a completion claims its mapping before the kill, or
    /// its epoch is stale after it; never both (ticket-fate exactness).
    inv_tickets: Mutex<HashMap<InvocationId, Ticket>>,
    /// Lifecycle state ([`ShardHealth`] as usize); written only under
    /// the router write lock (membership verbs), read lock-free by
    /// routing and `membership`.
    health: AtomicUsize,
    /// Kill counter: bumped under the plane lock when the plane is
    /// rebuilt; see [`WorkItem`].
    epoch: AtomicU64,
    /// Completions retired on this shard (survives plane rebuilds, so
    /// the per-shard `stats` breakdown stays monotone across kills).
    completed: AtomicU64,
    /// Cold starts among those completions (per-shard cold ratio).
    cold_starts: AtomicU64,
}

const HEALTH_UP: usize = 0;
const HEALTH_DRAINING: usize = 1;
const HEALTH_DEAD: usize = 2;

fn health_of(v: usize) -> ShardHealth {
    match v {
        HEALTH_DRAINING => ShardHealth::Draining,
        HEALTH_DEAD => ShardHealth::Dead,
        _ => ShardHealth::Up,
    }
}

fn health_code(h: ShardHealth) -> usize {
    match h {
        ShardHealth::Up => HEALTH_UP,
        ShardHealth::Draining => HEALTH_DRAINING,
        ShardHealth::Dead => HEALTH_DEAD,
    }
}

impl ShardState {
    fn new(plane: ControlPlane, capacity: f64) -> Self {
        Self {
            plane: Mutex::new(plane),
            pending: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            capacity,
            work: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            gate: Mutex::new(false),
            gate_cv: Condvar::new(),
            ticks: AtomicU64::new(0),
            inv_tickets: Mutex::new(HashMap::new()),
            health: AtomicUsize::new(HEALTH_UP),
            epoch: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            cold_starts: AtomicU64::new(0),
        }
    }

    fn depth(&self) -> usize {
        self.pending.load(Ordering::SeqCst) + self.in_flight.load(Ordering::SeqCst)
    }

    fn health(&self) -> ShardHealth {
        health_of(self.health.load(Ordering::SeqCst))
    }

    fn set_health(&self, h: ShardHealth) {
        self.health.store(health_code(h), Ordering::SeqCst);
    }

    fn load(&self) -> ShardLoad {
        ShardLoad {
            pending: self.pending.load(Ordering::SeqCst),
            in_flight: self.in_flight.load(Ordering::SeqCst),
            capacity: self.capacity,
            routable: self.health.load(Ordering::SeqCst) == HEALTH_UP,
        }
    }

    /// Publish the plane's load counters (call under the plane lock).
    fn publish(&self, plane: &ControlPlane) {
        self.pending.store(plane.pending(), Ordering::SeqCst);
        self.in_flight.store(plane.in_flight(), Ordering::SeqCst);
    }

    fn push_work(&self, item: WorkItem) {
        self.work.lock().unwrap().push_back(item);
        self.work_cv.notify_one();
    }

    /// Wake a (possibly) parked monitor: a submit landed on this shard.
    fn wake_monitor(&self) {
        let mut g = self.gate.lock().unwrap();
        *g = true;
        self.gate_cv.notify_one();
    }
}

/// Shared serving state: shards, router, tickets, executor, timer.
struct Inner {
    /// Frontend kind for `describe`: `rt-server` or `rt-cluster`.
    kind: &'static str,
    router_name: &'static str,
    shards: Vec<ShardState>,
    /// Routing decision for each arrival. Read-mostly: every submit
    /// takes the read lock (routers mutate through atomics), so
    /// concurrent submits route in parallel.
    router: RwLock<Box<dyn Router>>,
    clock: RealClock,
    /// Modeled-delay scale: 1 virtual second sleeps `scale` real seconds.
    scale: f64,
    exec_tx: Option<Sender<ExecJob>>,
    /// Ticket registry, sharded by `ticket % TICKET_SHARDS`.
    tickets: Vec<Mutex<TicketTable>>,
    /// Lock-free admission lookup: registered name *and* class name →
    /// id, precomputed from the workload (identical on every shard) so
    /// submits never scan — or allocate — under a plane lock.
    func_index: HashMap<String, FuncId>,
    /// FuncId → registered name (reply field), precomputed so the
    /// completion path never locks a plane for a name.
    func_names: Vec<String>,
    /// FuncId → catalog class name (PJRT artifact key).
    class_names: Vec<&'static str>,
    /// Precomputed `describe` fields (identical on every shard).
    policy: String,
    functions: Vec<String>,
    timer: Timer,
    next_ticket: AtomicU64,
    /// Admission bound on total queued work (`usize::MAX` = unlimited).
    max_pending: AtomicUsize,
    /// Shared with every event loop serving this frontend, so the
    /// guard's shutdown also winds down poller threads.
    running: Arc<AtomicBool>,
    // O(1) stats aggregates, maintained at completion time.
    completed: AtomicUsize,
    lat_sum_ns: AtomicU64,
    cold_starts: AtomicUsize,
    /// Executor-side threads spawned (timer + workers): a function of
    /// configuration, asserted by tests to be load-independent.
    exec_threads: AtomicUsize,
    // --- elastic membership (see module docs) ------------------------
    /// Kept for cold plane rebuilds after a kill.
    workload: Workload,
    /// Per-shard plane configs, kept for the same reason.
    plane_cfgs: Vec<PlaneConfig>,
    /// Cluster-wide membership change counter (drain/join/kill).
    membership_epoch: AtomicU64,
    // Ticket-fate conservation counters:
    // accepted == completed + failed + outstanding, always.
    accepted: AtomicU64,
    failed: AtomicU64,
    rejected: AtomicU64,
    stale_drops: AtomicU64,
    // --- observability (see module docs) ------------------------------
    /// Shared metrics registry + trace ring; every plane holds a
    /// [`crate::telemetry::ShardSink`] onto the same instance.
    telemetry: Arc<Telemetry>,
    /// Router spill watermark for the `route` trace event's spill flag.
    /// Concurrent submits may attribute a spill to a racing neighbor —
    /// the flag is observational; the cumulative count conserves.
    last_spills: AtomicU64,
}

impl Inner {
    fn ticket_slot(&self, id: u64) -> &Mutex<TicketTable> {
        &self.tickets[(id % TICKET_SHARDS as u64) as usize]
    }

    /// Wake every parked/sleeping background thread for shutdown. Each
    /// notify holds the matching mutex so a thread between its
    /// `running` check and its wait cannot miss the wakeup.
    fn wake_all(&self) {
        {
            let _g = self.timer.heap.lock().unwrap();
            self.timer.cv.notify_all();
        }
        for s in &self.shards {
            {
                let _g = s.work.lock().unwrap();
                s.work_cv.notify_all();
            }
            {
                let _g = s.gate.lock().unwrap();
                s.gate_cv.notify_all();
            }
        }
    }
}

/// Cloneable, shutdown-free view of a running frontend. Connections and
/// embedders hold these; only the constructor-returned guard can stop
/// the server.
#[derive(Clone)]
pub struct RtHandle {
    inner: Arc<Inner>,
}

// ---------------------------------------------------------------------
// Frontend implementation over Inner.
// ---------------------------------------------------------------------

fn describe_inner(inner: &Arc<Inner>) -> DescribeInfo {
    DescribeInfo {
        proto: PROTOCOL_VERSION,
        server: inner.kind.to_string(),
        policy: inner.policy.clone(),
        shards: inner.shards.len(),
        router: inner.router_name.to_string(),
        functions: inner.functions.clone(),
    }
}

fn submit_inner(inner: &Arc<Inner>, name: &str) -> Result<Ticket, ApiError> {
    let r = submit_raw(inner, name);
    if r.is_err() {
        // Admission rejections leave nothing outstanding: no ticket, no
        // plane arrival — counted apart from accepted work.
        inner.rejected.fetch_add(1, Ordering::SeqCst);
    }
    r
}

fn submit_raw(inner: &Arc<Inner>, name: &str) -> Result<Ticket, ApiError> {
    if !inner.running.load(Ordering::SeqCst) {
        return Err(ApiError::ShuttingDown);
    }
    let Some(&func) = inner.func_index.get(name) else {
        return Err(ApiError::UnknownFunction {
            name: name.to_string(),
        });
    };
    // Admission control + routing over the published atomics: no plane
    // lock until the routed shard is known, and no steady-state
    // allocation — the load snapshot lives in a per-thread buffer.
    thread_local! {
        static LOADS_BUF: std::cell::RefCell<Vec<ShardLoad>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }
    let route = || {
        LOADS_BUF.with(|buf| -> Result<(usize, u64), ApiError> {
            let mut loads = buf.borrow_mut();
            loads.clear();
            loads.extend(inner.shards.iter().map(|s| s.load()));
            let pending: usize = loads.iter().map(|l| l.pending).sum();
            let limit = inner.max_pending.load(Ordering::SeqCst);
            if pending >= limit {
                return Err(ApiError::Overloaded {
                    pending,
                    limit,
                    retry_after_ms: 0,
                });
            }
            // Spills are read under the same router lock as the route
            // decision, so the pair is coherent per call.
            let router = inner.router.read().unwrap();
            Ok((router.route(func, &loads), router.spills()))
        })
    };
    let ticket = Ticket(inner.next_ticket.fetch_add(1, Ordering::SeqCst));
    inner
        .ticket_slot(ticket.0)
        .lock()
        .unwrap()
        .insert_pending(ticket.0);
    // A kill can land between routing and the plane lock; the routed
    // shard's health is re-checked under its plane lock (where kills
    // flip it), and a dead hit re-routes — the healed loads now show
    // the shard unroutable. Bounded: each retry needs a fresh kill.
    let mut attempts = 0;
    loop {
        let (shard, spills) = match route() {
            Ok(s) => s,
            Err(e) => {
                // Nothing accepted: retract the provisional ticket.
                inner.ticket_slot(ticket.0).lock().unwrap().remove(ticket.0);
                return Err(e);
            }
        };
        debug_assert!(shard < inner.shards.len(), "router out of range");
        let st = &inner.shards[shard];
        // Route event, emitted before the plane lock so it precedes the
        // plane's own submit/enqueue events in ring order. A dead-shard
        // retry emits a second route — the re-route is real.
        {
            let spilled = inner.last_spills.swap(spills, Ordering::SeqCst) < spills;
            if spilled {
                inner.telemetry.registry.shard(shard as u32).spills.inc();
            }
            inner.telemetry.emit(
                TraceEvent::new(inner.clock.now(), EventKind::Route, shard as u32)
                    .func(func.0)
                    .a(st.epoch.load(Ordering::SeqCst) as i64)
                    .b(spilled as i64),
            );
        }
        let (was_idle, ds, epoch) = {
            // The only plane lock on the submit path: the routed shard's.
            let mut plane = st.plane.lock().unwrap();
            if st.health() == ShardHealth::Dead {
                drop(plane);
                attempts += 1;
                if attempts > inner.shards.len() {
                    inner.ticket_slot(ticket.0).lock().unwrap().remove(ticket.0);
                    return Err(ApiError::ShuttingDown);
                }
                continue;
            }
            let now = inner.clock.now();
            // Fault-layer admission gate (circuit breaker, deadline
            // shed) under the same lock as the arrival, so the breaker
            // state it reads is the state the arrival would feed. A
            // refusal is a rejection, not a fate: retract the
            // provisional ticket, nothing entered the plane.
            if let Err(e) = plane.try_admit(func, now) {
                drop(plane);
                inner.ticket_slot(ticket.0).lock().unwrap().remove(ticket.0);
                return Err(match e {
                    AdmitError::Quarantined { retry_after_ms } => ApiError::Quarantined {
                        func: inner.func_names[func.0 as usize].clone(),
                        retry_after_ms,
                    },
                    AdmitError::Overloaded { retry_after_ms } => {
                        // Shed refuses at the current depth — report it
                        // as the bound that was hit.
                        let depth = st.pending.load(Ordering::SeqCst)
                            + st.in_flight.load(Ordering::SeqCst);
                        ApiError::Overloaded {
                            pending: depth,
                            limit: depth,
                            retry_after_ms,
                        }
                    }
                });
            }
            // Exact idle check under the lock (a pre-lock snapshot could
            // race a completion and leave the monitor parked with work).
            let was_idle = plane.pending() + plane.in_flight() == 0;
            let (inv, ds) = plane.on_arrival(func, now);
            // Map under the plane lock (see ShardState::inv_tickets).
            st.inv_tickets.lock().unwrap().insert(inv, ticket);
            st.publish(&plane);
            (was_idle, ds, st.epoch.load(Ordering::SeqCst))
        };
        inner.accepted.fetch_add(1, Ordering::SeqCst);
        if was_idle {
            st.wake_monitor();
        }
        schedule_dispatches(inner, shard, epoch, ds);
        return Ok(ticket);
    }
}

fn wait_inner(
    inner: &Arc<Inner>,
    ticket: Ticket,
    deadline: Option<Duration>,
) -> Result<InvokeOutcome, ApiError> {
    let rx = {
        let mut tickets = inner.ticket_slot(ticket.0).lock().unwrap();
        match tickets.remove(ticket.0) {
            None => {
                return Err(ApiError::UnknownTicket {
                    ticket,
                    evicted: tickets.was_evicted(ticket.0),
                })
            }
            // Already resolved: claiming removes the entry.
            Some(TicketEntry::Done(o)) => return Ok(o),
            Some(TicketEntry::Failed(e)) => return Err(e),
            Some(TicketEntry::Pending { mut waiters }) => {
                let (tx, rx) = channel();
                waiters.push(Waiter::Chan(tx));
                tickets
                    .entries
                    .insert(ticket.0, TicketEntry::Pending { waiters });
                rx
            }
        }
    };
    let resolution = match deadline {
        // Expired: report the ticket so the (possibly sync-invoking)
        // client can still redeem the run-to-completion invocation.
        Some(dl) => rx.recv_timeout(dl).map_err(|_| ApiError::DeadlineExceeded {
            waited_ms: dl.as_millis() as u64,
            ticket: Some(ticket),
        })?,
        // Sender-side drop (process teardown) surfaces as shutdown.
        None => rx.recv().map_err(|_| ApiError::ShuttingDown)?,
    };
    // Claimed — outcome or structured fate (e.g. shard-lost): reclaim
    // the entry (concurrent waiters were all woken by the same
    // resolution; whichever removes second is a no-op).
    inner.ticket_slot(ticket.0).lock().unwrap().remove(ticket.0);
    resolution
}

fn poll_inner(inner: &Arc<Inner>, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
    let mut tickets = inner.ticket_slot(ticket.0).lock().unwrap();
    match tickets.remove(ticket.0) {
        None => Err(ApiError::UnknownTicket {
            ticket,
            evicted: tickets.was_evicted(ticket.0),
        }),
        // Resolved: claiming removes the entry, like a successful wait.
        Some(TicketEntry::Done(o)) => Ok(Some(o)),
        Some(TicketEntry::Failed(e)) => Err(e),
        Some(pending @ TicketEntry::Pending { .. }) => {
            tickets.entries.insert(ticket.0, pending);
            Ok(None)
        }
    }
}

/// Register a push subscription: deliver `ticket`'s resolution to
/// `sink` instead of blocking a thread. An already-terminal ticket is
/// delivered immediately *without* claiming it — the subscriber claims
/// on actual delivery to a live connection, so the ticket survives a
/// subscriber that disconnects first (redeem-after-disconnect parity
/// with the deadline-tripped blocking wait).
fn subscribe_inner(
    inner: &Arc<Inner>,
    ticket: Ticket,
    sink: Arc<dyn CompletionSink>,
    conn: u64,
    tag: u64,
) -> Result<(), ApiError> {
    let mut tickets = inner.ticket_slot(ticket.0).lock().unwrap();
    // Existence is decided before taking the `get_mut` borrow the
    // Pending arm needs (the None arm would otherwise hold it while
    // asking `was_evicted`).
    if !tickets.entries.contains_key(&ticket.0) {
        return Err(ApiError::UnknownTicket {
            ticket,
            evicted: tickets.was_evicted(ticket.0),
        });
    }
    let resolved = match tickets.entries.get_mut(&ticket.0).expect("present: checked") {
        TicketEntry::Pending { waiters } => {
            waiters.push(Waiter::Push {
                sink: Arc::clone(&sink),
                conn,
                tag,
            });
            None
        }
        TicketEntry::Done(o) => Some(Ok(o.clone())),
        TicketEntry::Failed(e) => Some(Err(e.clone())),
    };
    drop(tickets);
    if let Some(result) = resolved {
        sink.complete(conn, tag, ticket, result);
    }
    Ok(())
}

/// O(shards) over atomics — never locks a plane. The aggregates
/// (completions, latency sum, cold starts) are bumped on the completion
/// path *after* the plane publishes its load, so a waiter that has just
/// been fulfilled observes its own invocation in the totals.
fn stats_inner(inner: &Arc<Inner>) -> StatsSnapshot {
    let n = inner.completed.load(Ordering::SeqCst);
    let mut s = StatsSnapshot {
        invocations: n,
        ..Default::default()
    };
    s.shards.reserve_exact(inner.shards.len());
    for (i, st) in inner.shards.iter().enumerate() {
        let pending = st.pending.load(Ordering::SeqCst);
        let in_flight = st.in_flight.load(Ordering::SeqCst);
        s.pending += pending;
        s.in_flight += in_flight;
        let completed = st.completed.load(Ordering::SeqCst);
        let cold = st.cold_starts.load(Ordering::SeqCst);
        s.shards.push(ShardStatsRow {
            shard: i,
            pending,
            in_flight,
            completed,
            cold_ratio: if completed > 0 {
                cold as f64 / completed as f64
            } else {
                0.0
            },
            health: st.health(),
            epoch: st.epoch.load(Ordering::SeqCst),
        });
    }
    if n > 0 {
        s.mean_latency_ms = inner.lat_sum_ns.load(Ordering::SeqCst) as f64 / n as f64 / 1e6;
        s.cold_ratio = inner.cold_starts.load(Ordering::SeqCst) as f64 / n as f64;
    }
    s
}

// ---------------------------------------------------------------------
// Elastic membership (see module docs).
// ---------------------------------------------------------------------

/// Lock-free membership snapshot: health/epoch/load per shard plus the
/// ticket-fate conservation counters. Never locks a plane.
fn membership_inner(inner: &Arc<Inner>) -> Result<MembershipInfo, ApiError> {
    Ok(MembershipInfo {
        epoch: inner.membership_epoch.load(Ordering::SeqCst),
        shards: inner
            .shards
            .iter()
            .enumerate()
            .map(|(i, st)| ShardInfo {
                shard: i,
                health: st.health(),
                epoch: st.epoch.load(Ordering::SeqCst),
                pending: st.pending.load(Ordering::SeqCst),
                in_flight: st.in_flight.load(Ordering::SeqCst),
                capacity: st.capacity,
            })
            .collect(),
        accepted: inner.accepted.load(Ordering::SeqCst),
        completed: inner.completed.load(Ordering::SeqCst) as u64,
        failed: inner.failed.load(Ordering::SeqCst),
        rejected: inner.rejected.load(Ordering::SeqCst),
        stale_drops: inner.stale_drops.load(Ordering::SeqCst),
    })
}

fn no_shard(shard: usize, n: usize) -> ApiError {
    ApiError::BadRequest {
        detail: format!("no shard {shard} (cluster has {n})"),
    }
}

fn live_count(inner: &Arc<Inner>) -> usize {
    inner
        .shards
        .iter()
        .filter(|s| s.health() == ShardHealth::Up)
        .count()
}

/// `drain`: stop routing new work to `shard`; queued/in-flight work
/// runs to completion on the draining plane. Idempotent on an
/// already-draining shard; refused for a dead shard and for the last
/// live one.
fn drain_inner(inner: &Arc<Inner>, shard: usize) -> Result<MembershipInfo, ApiError> {
    if shard >= inner.shards.len() {
        return Err(no_shard(shard, inner.shards.len()));
    }
    // Membership is rare: take the router's write side so the health
    // flip and the ring heal are one atomic step for routing.
    let mut router = inner.router.write().unwrap();
    let st = &inner.shards[shard];
    match st.health() {
        ShardHealth::Draining => {}
        ShardHealth::Dead => {
            return Err(ApiError::BadRequest {
                detail: format!("shard {shard} is dead; join it first"),
            })
        }
        ShardHealth::Up => {
            if live_count(inner) <= 1 {
                return Err(ApiError::BadRequest {
                    detail: "cannot drain the last live shard".into(),
                });
            }
            st.set_health(ShardHealth::Draining);
            router.on_shard_removed(shard);
        }
    }
    drop(router);
    inner.membership_epoch.fetch_add(1, Ordering::SeqCst);
    membership_inner(inner)
}

/// `join`: (re)insert `shard` into the routable set — exactly its
/// original ring vnodes come back, so no other shard's homes move. A
/// previously killed shard rejoins cold. Idempotent on an Up shard.
fn join_inner(inner: &Arc<Inner>, shard: usize) -> Result<MembershipInfo, ApiError> {
    if shard >= inner.shards.len() {
        return Err(no_shard(shard, inner.shards.len()));
    }
    let mut router = inner.router.write().unwrap();
    let st = &inner.shards[shard];
    if st.health() != ShardHealth::Up {
        st.set_health(ShardHealth::Up);
        router.on_shard_added(shard);
    }
    drop(router);
    inner.membership_epoch.fetch_add(1, Ordering::SeqCst);
    membership_inner(inner)
}

/// `kill`: abrupt shard failure. Under the shard's plane lock the plane
/// is replaced cold, the epoch is bumped (stale timer/work items will
/// be dropped, not delivered to id-recycling new invocations), and the
/// invocation→ticket map is drained; every stranded ticket then
/// resolves to [`ApiError::ShardLost`] — blocked waiters wake
/// immediately. Refused for the last live shard.
fn kill_inner(inner: &Arc<Inner>, shard: usize) -> Result<MembershipInfo, ApiError> {
    if shard >= inner.shards.len() {
        return Err(no_shard(shard, inner.shards.len()));
    }
    let mut router = inner.router.write().unwrap();
    let st = &inner.shards[shard];
    let was_up = match st.health() {
        ShardHealth::Dead => {
            return Err(ApiError::BadRequest {
                detail: format!("shard {shard} is already dead"),
            })
        }
        ShardHealth::Up => {
            if live_count(inner) <= 1 {
                return Err(ApiError::BadRequest {
                    detail: "cannot kill the last live shard".into(),
                });
            }
            true
        }
        ShardHealth::Draining => false,
    };
    let (stranded, new_epoch): (Vec<Ticket>, u64) = {
        let mut plane = st.plane.lock().unwrap();
        let mut fresh = ControlPlane::new(
            inner.workload.clone(),
            inner.plane_cfgs[shard].clone(),
        );
        // The rebuilt plane keeps observing: same registry, same ring.
        fresh.attach_telemetry(inner.telemetry.clone(), shard as u32);
        *plane = fresh;
        // Health, epoch, and the ticket-map drain all happen under the
        // plane lock: a racing completion either claimed its mapping
        // before us or sees a stale epoch after us — never both.
        st.set_health(ShardHealth::Dead);
        let new_epoch = st.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        st.publish(&plane);
        (
            st.inv_tickets
                .lock()
                .unwrap()
                .drain()
                .map(|(_, t)| t)
                .collect(),
            new_epoch,
        )
    };
    if was_up {
        router.on_shard_removed(shard);
    }
    drop(router);
    let now = inner.clock.now();
    inner.telemetry.emit(
        TraceEvent::new(now, EventKind::Epoch, shard as u32)
            .a(new_epoch as i64)
            .b(stranded.len() as i64),
    );
    let sm = inner.telemetry.registry.shard(shard as u32);
    for ticket in stranded {
        inner.failed.fetch_add(1, Ordering::SeqCst);
        sm.errors.inc();
        inner.telemetry.emit(TraceEvent::new(now, EventKind::Error, shard as u32));
        fail_ticket(inner, ticket, ApiError::ShardLost { shard, ticket });
    }
    inner.membership_epoch.fetch_add(1, Ordering::SeqCst);
    membership_inner(inner)
}

// ---------------------------------------------------------------------
// Telemetry export (see module docs, "Observability").
// ---------------------------------------------------------------------

/// Render the metrics registry. Registry reads are `Relaxed` atomic
/// loads; the only allocation is the response body itself.
fn metrics_inner(inner: &Arc<Inner>, format: MetricsFormat) -> Result<String, ApiError> {
    Ok(match format {
        MetricsFormat::Prom => inner.telemetry.render_prometheus(),
        MetricsFormat::Json => inner.telemetry.to_json().render_compact(),
    })
}

/// Drain up to `max` events from the trace ring (oldest-first) plus the
/// cumulative overflow-drop counter.
fn trace_inner(inner: &Arc<Inner>, max: usize) -> Result<(u64, Vec<TraceEvent>), ApiError> {
    let events = inner.telemetry.trace.drain(max);
    Ok((inner.telemetry.dropped_events(), events))
}

/// Resolve a ticket to a structured error and wake every waiter —
/// the failure-path twin of [`fulfill`].
fn fail_ticket(inner: &Arc<Inner>, ticket: Ticket, err: ApiError) {
    let prev = inner
        .ticket_slot(ticket.0)
        .lock()
        .unwrap()
        .fail(ticket.0, err.clone());
    if let Some(TicketEntry::Pending { waiters }) = prev {
        for w in waiters {
            w.notify(ticket, Err(err.clone()));
        }
    }
}

/// Single copy of the [`Frontend`] wiring, stamped onto every type that
/// exposes the shared `Inner` (the handle and both guards — identical
/// behavior by construction). `shutdown` only flips admission; joining
/// the background threads needs a guard's own `stop()` or `Drop`.
macro_rules! impl_frontend_via_inner {
    ($ty:ty) => {
        impl Frontend for $ty {
            fn describe(&self) -> DescribeInfo {
                describe_inner(&self.inner)
            }
            fn submit(&self, func: &str) -> Result<Ticket, ApiError> {
                submit_inner(&self.inner, func)
            }
            fn wait(
                &self,
                ticket: Ticket,
                deadline: Option<Duration>,
            ) -> Result<InvokeOutcome, ApiError> {
                wait_inner(&self.inner, ticket, deadline)
            }
            fn poll(&self, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
                poll_inner(&self.inner, ticket)
            }
            fn subscribe(
                &self,
                ticket: Ticket,
                sink: Arc<dyn CompletionSink>,
                conn: u64,
                tag: u64,
            ) -> Result<(), ApiError> {
                subscribe_inner(&self.inner, ticket, sink, conn, tag)
            }
            fn stats(&self) -> StatsSnapshot {
                stats_inner(&self.inner)
            }
            fn shutdown(&self) {
                self.inner.running.store(false, Ordering::SeqCst);
            }
            fn drain(&self, shard: usize) -> Result<MembershipInfo, ApiError> {
                drain_inner(&self.inner, shard)
            }
            fn join(&self, shard: usize) -> Result<MembershipInfo, ApiError> {
                join_inner(&self.inner, shard)
            }
            fn kill(&self, shard: usize) -> Result<MembershipInfo, ApiError> {
                kill_inner(&self.inner, shard)
            }
            fn membership(&self) -> Result<MembershipInfo, ApiError> {
                membership_inner(&self.inner)
            }
            fn metrics(&self, format: MetricsFormat) -> Result<String, ApiError> {
                metrics_inner(&self.inner, format)
            }
            fn trace(
                &self,
                max: usize,
            ) -> Result<(u64, Vec<crate::telemetry::TraceEvent>), ApiError> {
                trace_inner(&self.inner, max)
            }
        }
    };
}

impl_frontend_via_inner!(RtHandle);
impl_frontend_via_inner!(RtServer);
impl_frontend_via_inner!(RtCluster);

/// Single copy of the shutdown-guard surface, stamped onto both guards
/// (`RtServer`, `RtCluster`): handle/serve/backpressure/diagnostics
/// plus the stop-and-join that only a guard — never a dropped
/// connection handle — may trigger.
macro_rules! impl_guard {
    ($ty:ty) => {
        impl $ty {
            /// Cloneable, shutdown-free view for connections and embedding.
            pub fn handle(&self) -> RtHandle {
                RtHandle {
                    inner: Arc::clone(&self.inner),
                }
            }

            /// Serve the protocol on `addr` (port 0 picks a free one)
            /// with default event-loop limits.
            pub fn serve(&self, addr: &str) -> anyhow::Result<std::net::SocketAddr> {
                self.serve_cfg(addr, event_loop::LoopConfig::default())
            }

            /// [`Self::serve`] with explicit event-loop limits (slow-
            /// client outbound cap, line cap, connection cap).
            pub fn serve_cfg(
                &self,
                addr: &str,
                cfg: event_loop::LoopConfig,
            ) -> anyhow::Result<std::net::SocketAddr> {
                serve_on(self.handle(), addr, cfg)
            }

            /// Backpressure bound: reject (`overloaded`) when total
            /// queued work is at/above `limit` at submit time.
            pub fn set_max_pending(&self, limit: usize) {
                self.inner.max_pending.store(limit, Ordering::SeqCst);
            }

            /// Executor-side threads spawned (timer + worker pools) —
            /// a function of configuration, never of offered load.
            pub fn exec_threads(&self) -> usize {
                self.inner.exec_threads.load(Ordering::SeqCst)
            }

            /// Monitor ticks that locked a plane, summed over shards.
            /// Stays flat while the server is idle (monitors park).
            pub fn monitor_ticks(&self) -> u64 {
                self.inner
                    .shards
                    .iter()
                    .map(|s| s.ticks.load(Ordering::SeqCst))
                    .sum()
            }

            /// Stop admissions and join the background threads (timer,
            /// workers, monitors). Idempotent; also runs on `Drop`.
            /// Only this guard stops the server — dropped connection
            /// handles never do.
            pub fn stop(&self) {
                self.inner.running.store(false, Ordering::SeqCst);
                self.inner.wake_all();
                for h in self.threads.lock().unwrap().drain(..) {
                    let _ = h.join();
                }
            }
        }

        impl Drop for $ty {
            fn drop(&mut self) {
                self.stop();
            }
        }
    };
}

// ---------------------------------------------------------------------
// Construction + background threads.
// ---------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn build_inner(
    kind: &'static str,
    router_name: &'static str,
    workload: Workload,
    plane_cfgs: Vec<PlaneConfig>,
    router: Box<dyn Router>,
    capacities: Vec<f64>,
    artifacts_dir: Option<&std::path::Path>,
    scale: f64,
) -> anyhow::Result<Arc<Inner>> {
    assert!(scale > 0.0);
    let exec_tx = match artifacts_dir {
        Some(dir) => Some(spawn_executor(dir, &workload)?),
        None => None,
    };
    // Admission index, first match wins like the old linear scan:
    // registered name (unique) and class name (first copy). Names are
    // precomputed per FuncId so neither submit nor completion ever
    // allocates or locks a plane for one.
    let mut func_index = HashMap::new();
    let mut func_names = vec![String::new(); workload.len()];
    let mut class_names = vec![""; workload.len()];
    let mut functions = Vec::with_capacity(workload.len());
    for f in &workload.funcs {
        func_index.entry(f.name.clone()).or_insert(f.id);
        func_index.entry(f.class.name.to_string()).or_insert(f.id);
        func_names[f.id.0 as usize] = f.name.clone();
        class_names[f.id.0 as usize] = f.class.name;
        functions.push(f.name.clone());
    }
    let mut planes: Vec<ControlPlane> = plane_cfgs
        .iter()
        .map(|cfg| ControlPlane::new(workload.clone(), cfg.clone()))
        .collect();
    let policy = planes[0].policy_name().to_string();
    // One registry + ring for the whole frontend; each plane gets a
    // shard-scoped sink so the wire path emits the same lifecycle
    // vocabulary the simulator does.
    let device_counts: Vec<usize> = plane_cfgs.iter().map(|c| c.n_devices()).collect();
    let (class_labels, _) = telemetry::workload_classes(&workload);
    let tel = Arc::new(Telemetry::new(&device_counts, &class_labels));
    for (s, plane) in planes.iter_mut().enumerate() {
        plane.attach_telemetry(tel.clone(), s as u32);
    }
    let shards = planes
        .into_iter()
        .zip(capacities)
        .map(|(plane, cap)| ShardState::new(plane, cap))
        .collect();
    Ok(Arc::new(Inner {
        kind,
        router_name,
        shards,
        router: RwLock::new(router),
        clock: RealClock::new(),
        scale,
        exec_tx,
        tickets: (0..TICKET_SHARDS)
            .map(|_| Mutex::new(TicketTable::with_max(
                TicketTable::DEFAULT_MAX_DONE / TICKET_SHARDS,
            )))
            .collect(),
        func_index,
        func_names,
        class_names,
        policy,
        functions,
        timer: Timer::new(),
        next_ticket: AtomicU64::new(0),
        max_pending: AtomicUsize::new(usize::MAX),
        running: Arc::new(AtomicBool::new(true)),
        completed: AtomicUsize::new(0),
        lat_sum_ns: AtomicU64::new(0),
        cold_starts: AtomicUsize::new(0),
        exec_threads: AtomicUsize::new(0),
        workload,
        plane_cfgs,
        membership_epoch: AtomicU64::new(0),
        accepted: AtomicU64::new(0),
        failed: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
        stale_drops: AtomicU64::new(0),
        telemetry: tel,
        last_spills: AtomicU64::new(0),
    }))
}

/// Spawn the fixed background set: the timer thread, `workers` pool
/// threads per shard, and one monitor per shard. This is the *only*
/// place serving threads are created — nothing on the per-request or
/// per-dispatch path spawns.
fn spawn_threads(inner: &Arc<Inner>, workers: usize) -> Vec<thread::JoinHandle<()>> {
    assert!(workers >= 1, "worker pool needs at least one thread");
    let mut hs = Vec::with_capacity(1 + inner.shards.len() * (workers + 1));
    inner.exec_threads.fetch_add(1, Ordering::SeqCst);
    {
        let t = Arc::clone(inner);
        hs.push(thread::spawn(move || timer_loop(t)));
    }
    for shard in 0..inner.shards.len() {
        for _ in 0..workers {
            inner.exec_threads.fetch_add(1, Ordering::SeqCst);
            let t = Arc::clone(inner);
            hs.push(thread::spawn(move || worker_loop(t, shard)));
        }
        let t = Arc::clone(inner);
        hs.push(thread::spawn(move || monitor_loop(t, shard)));
    }
    hs
}

/// Timer thread: sleep until the earliest deadline, then hand the due
/// event to its shard's worker pool. Never locks a plane.
fn timer_loop(inner: Arc<Inner>) {
    let mut heap = inner.timer.heap.lock().unwrap();
    loop {
        if !inner.running.load(Ordering::SeqCst) {
            return;
        }
        let now = Instant::now();
        let next_due = heap.peek().map(|r| r.0.due);
        match next_due {
            None => {
                heap = inner.timer.cv.wait(heap).unwrap();
            }
            Some(due) if due <= now => {
                let Reverse(e) = heap.pop().unwrap();
                drop(heap);
                inner.shards[e.shard].push_work(e.item);
                heap = inner.timer.heap.lock().unwrap();
            }
            Some(due) => {
                let (h, _) = inner
                    .timer
                    .cv
                    .wait_timeout(heap, due - now)
                    .unwrap();
                heap = h;
            }
        }
    }
}

/// Worker thread: drain the shard's inbox. Model-mode items are pure
/// bookkeeping (no sleeping); PJRT items block on the executor, which
/// bounds concurrent jobs at the pool size.
fn worker_loop(inner: Arc<Inner>, shard: usize) {
    loop {
        let item = {
            let mut q = inner.shards[shard].work.lock().unwrap();
            loop {
                if let Some(item) = q.pop_front() {
                    break Some(item);
                }
                if !inner.running.load(Ordering::SeqCst) {
                    break None;
                }
                q = inner.shards[shard].work_cv.wait(q).unwrap();
            }
        };
        match item {
            None => return,
            Some(WorkItem::ExecStart { d, epoch }) => run_exec_start(&inner, shard, epoch, d),
            Some(WorkItem::Complete { d, exec_t0, epoch }) => {
                run_complete(&inner, shard, epoch, d, exec_t0)
            }
        }
    }
}

/// Monitor thread for one shard: scaled-free 200 ms-class ticks (the
/// shard's own `monitor_period`, real time), exactly like the paper's
/// NVML poller — utilization sampling, dynamic D, TTL expiry. Parks on
/// the shard's gate while idle: an idle server's planes see no
/// tick-driven lock traffic at all (TTL expiry resumes with the next
/// submit, whose tick fires at current wall time).
fn monitor_loop(inner: Arc<Inner>, shard: usize) {
    let st = &inner.shards[shard];
    let period = Duration::from_nanos(st.plane.lock().unwrap().cfg.monitor_period);
    // Failsafe recheck while parked: the submit-side wake is exact
    // (idleness is decided under the plane lock), so this is pure
    // defense in depth — a recheck wakes the thread but never ticks an
    // idle plane.
    let failsafe = period.saturating_mul(64).max(Duration::from_millis(100));
    while inner.running.load(Ordering::SeqCst) {
        if st.depth() == 0 {
            let mut g = st.gate.lock().unwrap();
            while !*g && inner.running.load(Ordering::SeqCst) && st.depth() == 0 {
                let (gg, _) = st.gate_cv.wait_timeout(g, failsafe).unwrap();
                g = gg;
            }
            *g = false;
            continue;
        }
        thread::sleep(period);
        if !inner.running.load(Ordering::SeqCst) {
            return;
        }
        let now = inner.clock.now();
        let (ds, epoch, fated) = {
            let mut plane = st.plane.lock().unwrap();
            let ds = plane.on_monitor_tick(now);
            // The tick runs fault maintenance (scheduled device
            // failures, the straggler watchdog); claim any resulting
            // retry-exhausted fates under the same lock.
            let fated = claim_fault_fates(st, &mut plane);
            st.publish(&plane);
            (ds, st.epoch.load(Ordering::SeqCst), fated)
        };
        st.ticks.fetch_add(1, Ordering::SeqCst);
        resolve_fault_fates(&inner, shard, fated);
        schedule_dispatches(&inner, shard, epoch, ds);
    }
}

/// PJRT executor thread: owns the (non-Send) runtime; executes one
/// artifact at a time. The serialization is harmless — the CPU PJRT
/// client is itself internally parallel and stands in for one GPU.
fn spawn_executor(
    dir: &std::path::Path,
    workload: &Workload,
) -> anyhow::Result<Sender<ExecJob>> {
    let (tx, rx): (Sender<ExecJob>, Receiver<ExecJob>) = channel();
    let dir = dir.to_path_buf();
    let names: Vec<&'static str> = {
        let mut v: Vec<&'static str> =
            workload.funcs.iter().map(|f| f.class.name).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
    thread::spawn(move || {
        let mut rt = match PjrtRuntime::new(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        for name in &names {
            if let Err(e) = rt.load_function(name) {
                let _ = ready_tx.send(Err(e));
                return;
            }
        }
        let _ = ready_tx.send(Ok(()));
        while let Ok(job) = rx.recv() {
            let t0 = std::time::Instant::now();
            let _ = rt.execute(job.artifact);
            let _ = job.reply.send(t0.elapsed());
        }
    });
    ready_rx.recv().expect("executor thread died")?;
    Ok(tx)
}

/// Scaled model-time → wall-clock duration.
fn scaled(scale: f64, ns: Nanos) -> Duration {
    Duration::from_secs_f64(to_secs(ns) * scale)
}

/// Park each dispatch on the timer until its (scaled) exec start,
/// stamped with the shard epoch it was scheduled under (callers read it
/// beneath the plane lock). The per-dispatch cost is one heap push —
/// no thread is spawned anywhere on this path.
fn schedule_dispatches(inner: &Arc<Inner>, shard: usize, epoch: u64, ds: Vec<Dispatch>) {
    if ds.is_empty() {
        return;
    }
    let now = Instant::now();
    for d in ds {
        let delay = scaled(inner.scale, d.exec_start.saturating_sub(d.at));
        inner
            .timer
            .schedule(now + delay, shard, WorkItem::ExecStart { d, epoch });
    }
}

/// The dispatch reached its exec start: touch the plane (the sim
/// engine's Touch event, live), then execute — PJRT inline on this
/// worker, or the modeled service as a timer event. A stale epoch
/// (the shard was killed since scheduling) drops the item instead:
/// the rebuilt plane has never heard of this invocation, and its
/// ticket was already failed by the kill.
fn run_exec_start(inner: &Arc<Inner>, shard: usize, epoch: u64, d: Dispatch) {
    let st = &inner.shards[shard];
    let exec_t0 = inner.clock.now();
    {
        // Exact utilization-integral touch at the wall-clock exec
        // start; the epoch check shares the lock so a kill cannot slip
        // between check and touch.
        let mut plane = st.plane.lock().unwrap();
        if st.epoch.load(Ordering::SeqCst) != epoch {
            inner.stale_drops.fetch_add(1, Ordering::SeqCst);
            return;
        }
        plane.touch(exec_t0);
    }
    if let Some(tx) = &inner.exec_tx {
        let (rtx, rrx) = channel();
        if tx
            .send(ExecJob {
                artifact: inner.class_names[d.func.0 as usize],
                reply: rtx,
            })
            .is_ok()
        {
            let _ = rrx.recv();
        }
        run_complete(inner, shard, epoch, d, exec_t0);
    } else {
        // Model mode: the worker never sleeps — completion fires from
        // the timer after the scaled modeled service time.
        inner.timer.schedule(
            Instant::now() + scaled(inner.scale, d.exec),
            shard,
            WorkItem::Complete { d, exec_t0, epoch },
        );
    }
}

/// Completion: retire the invocation on its plane, bump the stats
/// aggregates, fulfill the submitter's ticket, and schedule any
/// unlocked dispatches. Epoch-guarded like [`run_exec_start`]; the
/// ticket mapping is claimed under the plane lock so a concurrent kill
/// can never fail a ticket this path is about to fulfill.
///
/// Attempt-stamped for exactly-once under faults: the plane drops a
/// completion whose attempt was superseded (faulted + re-queued), and
/// a *faulted* attempt's completion returns no record — in both cases
/// the ticket mapping is left in place for the retry (or for the
/// retry-exhausted fate, resolved below).
fn run_complete(inner: &Arc<Inner>, shard: usize, epoch: u64, d: Dispatch, exec_t0: Nanos) {
    let st = &inner.shards[shard];
    let now = inner.clock.now();
    let (rec, ds, mapped, fated) = {
        let mut plane = st.plane.lock().unwrap();
        if st.epoch.load(Ordering::SeqCst) != epoch {
            inner.stale_drops.fetch_add(1, Ordering::SeqCst);
            return;
        }
        let (rec, ds) = plane.on_complete_attempt(d.inv, d.attempt, now);
        let fated = claim_fault_fates(st, &mut plane);
        st.publish(&plane);
        // Claim the mapping only when this completion actually retired
        // the invocation; a faulted/superseded attempt leaves the
        // ticket mapped for its retry.
        let mapped = if rec.is_some() {
            st.inv_tickets.lock().unwrap().remove(&d.inv)
        } else {
            None
        };
        (rec, ds, mapped, fated)
    };
    resolve_fault_fates(inner, shard, fated);
    // Completion matching: the plane hands back the completed
    // invocation's own record (not `records.last()`, which under
    // concurrent completions may belong to someone else).
    if let Some(rec) = rec {
        debug_assert_eq!(rec.inv, d.inv);
        let lat_ns = rec.completed.saturating_sub(rec.arrived);
        inner.lat_sum_ns.fetch_add(lat_ns, Ordering::SeqCst);
        if rec.start_kind == StartKind::Cold {
            inner.cold_starts.fetch_add(1, Ordering::SeqCst);
            st.cold_starts.fetch_add(1, Ordering::SeqCst);
        }
        inner.completed.fetch_add(1, Ordering::SeqCst);
        st.completed.fetch_add(1, Ordering::SeqCst);
        if let Some(ticket) = mapped {
            fulfill(
                inner,
                ticket,
                InvokeOutcome {
                    ticket,
                    func: inner.func_names[d.func.0 as usize].clone(),
                    shard,
                    gpu: rec.gpu.0,
                    start_kind: rec.start_kind,
                    latency_ms: to_secs(lat_ns) * 1e3,
                    exec_ms: to_secs(now.saturating_sub(exec_t0)) * 1e3,
                },
            );
        }
    }
    schedule_dispatches(inner, shard, epoch, ds);
}

/// Claim tickets for retry-exhausted invocations. Must run under the
/// plane lock: a fate's invocation→ticket mapping obeys the same
/// exactness rule as the completion path — a racing kill either sees
/// the mapping already claimed here, or drains it to `ShardLost`;
/// never both.
fn claim_fault_fates(st: &ShardState, plane: &mut ControlPlane) -> Vec<(Ticket, FaultFate)> {
    let fates = plane.drain_fault_fates();
    if fates.is_empty() {
        return Vec::new();
    }
    let mut map = st.inv_tickets.lock().unwrap();
    fates
        .into_iter()
        .filter_map(|f| map.remove(&f.inv).map(|t| (t, f)))
        .collect()
}

/// Resolve claimed retry-exhausted fates to [`ApiError::ExecFailed`]:
/// blocked waiters wake immediately with the structured error, exactly
/// like the kill path's `ShardLost`. Runs after the plane lock drops.
fn resolve_fault_fates(inner: &Arc<Inner>, shard: usize, fated: Vec<(Ticket, FaultFate)>) {
    if fated.is_empty() {
        return;
    }
    let now = inner.clock.now();
    let sm = inner.telemetry.registry.shard(shard as u32);
    for (ticket, fate) in fated {
        inner.failed.fetch_add(1, Ordering::SeqCst);
        sm.errors.inc();
        inner.telemetry.emit(
            TraceEvent::new(now, EventKind::Error, shard as u32)
                .func(fate.func.0)
                .a(fate.attempts as i64),
        );
        fail_ticket(
            inner,
            ticket,
            ApiError::ExecFailed {
                ticket,
                attempts: fate.attempts,
            },
        );
    }
}

/// Mark a ticket done and wake every waiter blocked on it.
fn fulfill(inner: &Arc<Inner>, ticket: Ticket, outcome: InvokeOutcome) {
    let prev = inner
        .ticket_slot(ticket.0)
        .lock()
        .unwrap()
        .complete(ticket.0, outcome.clone());
    if let Some(TicketEntry::Pending { waiters }) = prev {
        for w in waiters {
            w.notify(ticket, Ok(outcome.clone()));
        }
    }
}

/// Bind `addr` and serve the protocol from one event-loop (poller)
/// thread — every connection multiplexed, no per-connection threads
/// (see [`event_loop`]). The loop holds a cloned [`RtHandle`] (never
/// the shutdown guard — see the module docs) and exits when the shared
/// `running` flag clears.
fn serve_on(
    handle: RtHandle,
    addr: &str,
    cfg: event_loop::LoopConfig,
) -> anyhow::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let running = Arc::clone(&handle.inner.running);
    let tel = Some(Arc::clone(&handle.inner.telemetry));
    let el = event_loop::EventLoop::new(handle, listener, running, tel, cfg)?;
    thread::spawn(move || el.run());
    Ok(local)
}

// ---------------------------------------------------------------------
// RtServer: the single-plane frontend.
// ---------------------------------------------------------------------

/// Single-plane wall-clock frontend; the shutdown-owning guard.
/// Construct with [`RtServer::new`], serve TCP with [`RtServer::serve`],
/// embed via [`RtServer::handle`] or the [`Frontend`] impl.
pub struct RtServer {
    inner: Arc<Inner>,
    threads: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl RtServer {
    /// `artifacts_dir`: load + compile HLO artifacts and execute them on
    /// dispatch (real execution). `None`: sleep the modeled service time
    /// instead (pure control-plane demo). Worker pool defaults to
    /// [`DEFAULT_WORKERS`]; see [`RtServer::with_workers`].
    pub fn new(
        workload: Workload,
        cfg: PlaneConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
    ) -> anyhow::Result<Self> {
        Self::with_workers(workload, cfg, artifacts_dir, scale, DEFAULT_WORKERS)
    }

    /// [`RtServer::new`] with an explicit per-shard worker-pool size.
    pub fn with_workers(
        workload: Workload,
        cfg: PlaneConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
        workers: usize,
    ) -> anyhow::Result<Self> {
        let capacities = vec![cfg.fleet_capacity()];
        // Trivial ring: every routing question answers shard 0.
        let router = RouterKind::RoundRobin.build(1, 1.0, 0, &capacities);
        let inner = build_inner(
            "rt-server",
            "single",
            workload,
            vec![cfg],
            router,
            capacities,
            artifacts_dir,
            scale,
        )?;
        let threads = Mutex::new(spawn_threads(&inner, workers));
        Ok(Self { inner, threads })
    }
}

impl_guard!(RtServer);

// ---------------------------------------------------------------------
// RtCluster: N shards behind a live router.
// ---------------------------------------------------------------------

/// Sharded wall-clock frontend: N independent control planes behind a
/// [`crate::cluster::Router`], serving real TCP traffic. The shutdown-
/// owning guard, like [`RtServer`].
pub struct RtCluster {
    inner: Arc<Inner>,
    threads: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl RtCluster {
    /// Build `cfg.n_shards` planes (heterogeneous via
    /// [`ClusterConfig::shard_planes`]), the capacity-weighted router,
    /// and the fixed background set (timer, [`DEFAULT_WORKERS`] workers
    /// per shard, one monitor per shard).
    pub fn new(
        workload: Workload,
        cfg: ClusterConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
    ) -> anyhow::Result<Self> {
        Self::with_workers(workload, cfg, artifacts_dir, scale, DEFAULT_WORKERS)
    }

    /// [`RtCluster::new`] with an explicit per-shard worker-pool size.
    pub fn with_workers(
        workload: Workload,
        cfg: ClusterConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
        workers: usize,
    ) -> anyhow::Result<Self> {
        assert!(cfg.n_shards >= 1, "cluster needs at least one shard");
        assert!(
            cfg.shard_planes.is_empty() || cfg.shard_planes.len() == cfg.n_shards,
            "shard_planes must be empty or hold one config per shard"
        );
        let capacities = cfg.shard_capacities();
        let router = cfg
            .router
            .build(cfg.n_shards, cfg.load_factor, cfg.seed, &capacities);
        let planes: Vec<PlaneConfig> =
            (0..cfg.n_shards).map(|s| cfg.plane_for(s).clone()).collect();
        let inner = build_inner(
            "rt-cluster",
            cfg.router.name(),
            workload,
            planes,
            router,
            capacities,
            artifacts_dir,
            scale,
        )?;
        let threads = Mutex::new(spawn_threads(&inner, workers));
        Ok(Self { inner, threads })
    }

    pub fn n_shards(&self) -> usize {
        self.inner.shards.len()
    }
}

impl_guard!(RtCluster);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{BreakerConfig, FaultConfig, ShedConfig};
    use crate::types::{FuncId, MS, SEC};
    use crate::workload::catalog::by_name;

    fn workload() -> Workload {
        let mut w = Workload::default();
        w.register(by_name("isoneural").unwrap(), 0, 1.0);
        w.register(by_name("fft").unwrap(), 0, 1.0);
        w
    }

    fn fast_cfg() -> PlaneConfig {
        PlaneConfig {
            monitor_period: 20 * MS,
            ..Default::default()
        }
    }

    const WAIT: Option<Duration> = Some(Duration::from_secs(30));

    #[test]
    fn submit_completes_in_model_mode() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let ticket = srv.submit("isoneural-0").unwrap();
        let c = srv.wait(ticket, WAIT).unwrap();
        assert_eq!(c.ticket, ticket);
        assert_eq!(c.func, "isoneural-0");
        assert_eq!(c.shard, 0);
        assert_eq!(c.start_kind, StartKind::Cold);
        assert!(c.latency_ms > 0.0);
        let s = srv.stats();
        assert_eq!(s.invocations, 1);
        assert!(s.mean_latency_ms > 0.0);
        assert!((s.cold_ratio - 1.0).abs() < 1e-9);
        // Claimed tickets are reclaimed.
        assert_eq!(
            srv.wait(ticket, WAIT).unwrap_err().code(),
            "unknown-ticket"
        );
    }

    #[test]
    fn class_name_resolves_like_registered_name() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let t = srv.submit("fft").unwrap();
        assert_eq!(srv.wait(t, WAIT).unwrap().func, "fft-0");
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.0005).unwrap();
        let names = ["isoneural-0", "fft-0"];
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| srv.submit(names[i % 2]).unwrap())
            .collect();
        for t in tickets {
            srv.wait(t, WAIT).unwrap();
        }
        assert_eq!(srv.stats().invocations, 6);
    }

    #[test]
    fn poll_observes_pending_then_done() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.005).unwrap();
        let t = srv.submit("fft-0").unwrap();
        // fft's cold boot is seconds of model time — milliseconds here —
        // so the first poll observes it still running.
        assert_eq!(srv.poll(t).unwrap(), None);
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        let outcome = loop {
            if let Some(o) = srv.poll(t).unwrap() {
                break o;
            }
            assert!(std::time::Instant::now() < deadline, "poll never completed");
            thread::sleep(Duration::from_millis(2));
        };
        assert_eq!(outcome.ticket, t);
        // Consumed by the successful poll.
        assert_eq!(srv.poll(t).unwrap_err().code(), "unknown-ticket");
    }

    #[test]
    fn unknown_function_is_structured() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let err = srv.submit("ghost").unwrap_err();
        assert_eq!(err.code(), "unknown-function");
    }

    #[test]
    fn backpressure_rejects_overload_deterministically() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        srv.set_max_pending(1);
        // Default D=2 on one GPU: two dispatch immediately, the third
        // queues (pending=1), so the fourth submit hits the bound.
        let t1 = srv.submit("fft-0").unwrap();
        let t2 = srv.submit("fft-0").unwrap();
        let t3 = srv.submit("fft-0").unwrap();
        let err = srv.submit("fft-0").unwrap_err();
        assert_eq!(err.code(), "overloaded");
        for t in [t1, t2, t3] {
            srv.wait(t, WAIT).unwrap();
        }
    }

    #[test]
    fn shutdown_rejects_new_work_and_is_guard_owned() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let handle = srv.handle();
        // Dropping handles is inert — admission stays open.
        drop(handle.clone());
        assert!(handle.submit("isoneural-0").is_ok());
        srv.stop();
        assert_eq!(handle.submit("isoneural-0").unwrap_err().code(), "shutting-down");
        assert_eq!(srv.submit("isoneural-0").unwrap_err().code(), "shutting-down");
    }

    #[test]
    fn describe_reports_shape() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let d = srv.describe();
        assert_eq!(d.proto, PROTOCOL_VERSION);
        assert_eq!(d.server, "rt-server");
        assert_eq!(d.shards, 1);
        assert_eq!(d.router, "single");
        assert_eq!(d.policy, "mqfq-sticky");
        assert_eq!(d.functions, vec!["isoneural-0", "fft-0"]);
    }

    #[test]
    fn cluster_frontend_spreads_and_aggregates() {
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.001).unwrap();
        assert_eq!(srv.n_shards(), 2);
        let d = srv.describe();
        assert_eq!(d.server, "rt-cluster");
        assert_eq!(d.shards, 2);
        assert_eq!(d.router, "round-robin");
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| srv.submit("isoneural-0").unwrap())
            .collect();
        let shards: std::collections::HashSet<usize> = tickets
            .into_iter()
            .map(|t| srv.wait(t, WAIT).unwrap().shard)
            .collect();
        assert_eq!(shards.len(), 2, "round-robin must hit both shards");
        assert_eq!(srv.stats().invocations, 4);
    }

    #[test]
    fn telemetry_exports_metrics_trace_and_per_shard_stats() {
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.001).unwrap();
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| srv.submit("isoneural-0").unwrap())
            .collect();
        for t in tickets {
            srv.wait(t, WAIT).unwrap();
        }
        // Per-shard stats breakdown: counts conserve against the
        // aggregates, every shard is Up at epoch 0.
        let s = srv.stats();
        assert_eq!(s.shards.len(), 2);
        assert_eq!(s.shards.iter().map(|r| r.completed).sum::<u64>(), 4);
        for (i, row) in s.shards.iter().enumerate() {
            assert_eq!(row.shard, i);
            assert_eq!(row.health, ShardHealth::Up);
            assert_eq!(row.epoch, 0);
            assert!((row.cold_ratio - 1.0).abs() < 1e-9, "all-cold workload");
        }
        // Metrics registry: both formats render; the registry's own
        // completion counters agree with the stats path.
        let prom = srv.metrics(MetricsFormat::Prom).unwrap();
        assert!(prom.contains("# TYPE"));
        assert!(prom.contains("mqfq_completed_total"));
        let json = srv.metrics(MetricsFormat::Json).unwrap();
        assert!(json.contains("mqfq-metrics/v1"));
        let reg = &srv.inner.telemetry.registry;
        let completed: u64 = (0..2u32).map(|s| reg.shard(s).completed.get()).sum();
        assert_eq!(completed, 4);
        // Trace ring: the wire path emits the same lifecycle vocabulary
        // the simulator does, plus the serving-only route event.
        let (dropped, events) = srv.trace(usize::MAX).unwrap();
        assert_eq!(dropped, 0);
        let kinds: std::collections::HashSet<EventKind> =
            events.iter().map(|e| e.kind).collect();
        for k in [
            EventKind::Route,
            EventKind::Submit,
            EventKind::Enqueue,
            EventKind::Dispatch,
            EventKind::ExecStart,
            EventKind::Complete,
        ] {
            assert!(kinds.contains(&k), "missing {:?}", k);
        }
        assert_eq!(
            events.iter().filter(|e| e.kind == EventKind::Route).count(),
            4
        );
        // Drained is drained: a second trace call starts empty.
        assert!(srv.trace(usize::MAX).unwrap().1.is_empty());
    }

    #[test]
    fn cluster_sticky_keeps_a_function_home() {
        let cfg = ClusterConfig {
            n_shards: 4,
            router: RouterKind::StickyCh,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.0005).unwrap();
        let mut shards = std::collections::HashSet::new();
        for _ in 0..6 {
            let t = srv.submit("fft-0").unwrap();
            shards.insert(srv.wait(t, WAIT).unwrap().shard);
        }
        assert_eq!(shards.len(), 1, "light sticky load must stay home");
    }

    #[test]
    fn wait_deadline_trips_then_completion_is_recoverable() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.01).unwrap();
        // fft cold boot ≈ 2.4 s model time → ≈ 24 ms wall; 1 ms deadline
        // trips long before that.
        let t = srv.submit("fft-0").unwrap();
        let err = srv.wait(t, Some(Duration::from_millis(1))).unwrap_err();
        assert_eq!(err.code(), "deadline-exceeded");
        // Run-to-completion: the invocation still finishes and the
        // ticket stays redeemable.
        let o = srv.wait(t, WAIT).unwrap();
        assert_eq!(o.ticket, t);
        assert_eq!(srv.stats().invocations, 1);
    }

    #[test]
    fn unknown_ticket_rejected() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        assert_eq!(
            srv.wait(Ticket(999), WAIT).unwrap_err().code(),
            "unknown-ticket"
        );
        assert_eq!(srv.poll(Ticket(999)).unwrap_err().code(), "unknown-ticket");
    }

    #[test]
    fn ticket_table_bounds_unclaimed_completions() {
        let outcome = |n: u64| InvokeOutcome {
            ticket: Ticket(n),
            func: "f".into(),
            shard: 0,
            gpu: 0,
            start_kind: StartKind::Cold,
            latency_ms: 1.0,
            exec_ms: 1.0,
        };
        let mut t = TicketTable::with_max(2);
        for id in 0..5 {
            t.insert_pending(id);
            t.complete(id, outcome(id));
        }
        // Oldest unclaimed completions evicted down to the bound — and
        // remembered, so a late waiter gets the evicted hint rather
        // than a bare unknown-ticket.
        assert_eq!(t.done_count, 2);
        assert!(t.remove(0).is_none());
        assert!(t.remove(1).is_none());
        assert!(t.remove(2).is_none());
        assert!(t.was_evicted(0) && t.was_evicted(1) && t.was_evicted(2));
        assert!(!t.was_evicted(3) && !t.was_evicted(99));
        assert!(matches!(t.remove(3), Some(TicketEntry::Done(_))));
        assert!(matches!(t.remove(4), Some(TicketEntry::Done(_))));
        assert_eq!(t.done_count, 0);
        // Claimed-then-gone tickets are not "evicted".
        assert!(!t.was_evicted(3));
        // Promptly-claimed tickets leave stale order ids behind; the
        // compaction keeps both structures bounded.
        for id in 5..500 {
            t.insert_pending(id);
            t.complete(id, outcome(id));
            assert!(matches!(t.remove(id), Some(TicketEntry::Done(_))));
        }
        assert!(t.entries.is_empty());
        assert_eq!(t.done_count, 0);
        assert!(t.done_order.len() <= t.max_done.saturating_mul(2).max(64) + 1);
    }

    /// Poll `membership` until `pred` holds or the deadline passes.
    fn wait_membership<F: Fn(&MembershipInfo) -> bool>(
        f: &dyn Frontend,
        pred: F,
    ) -> MembershipInfo {
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            let m = f.membership().unwrap();
            if pred(&m) || std::time::Instant::now() > deadline {
                return m;
            }
            thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn kill_fails_stranded_tickets_immediately_and_conserves_fates() {
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        // Slow enough (fft cold boot ≈ 2.4 s model → ≈ 480 ms wall)
        // that all four invocations are still unresolved at kill time.
        let srv = RtCluster::new(workload(), cfg, None, 0.2).unwrap();
        let tickets: Vec<Ticket> = (0..4).map(|_| srv.submit("fft-0").unwrap()).collect();
        // A waiter already blocked on a doomed ticket (RR: tickets 0, 2
        // are shard 0's) must wake *immediately* with the structured
        // error — not hang until its 30 s deadline.
        let handle = srv.handle();
        let doomed = tickets[0];
        let waiter = thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let r = handle.wait(doomed, Some(Duration::from_secs(30)));
            (r, t0.elapsed())
        });
        thread::sleep(Duration::from_millis(10));
        let m = srv.kill(0).unwrap();
        assert_eq!(m.shards[0].health, ShardHealth::Dead);
        assert_eq!(m.shards[0].epoch, 1);
        assert_eq!(m.failed, 2, "both shard-0 tickets fail at kill time");
        let (r, waited) = waiter.join().unwrap();
        match r.unwrap_err() {
            ApiError::ShardLost { shard, ticket } => {
                assert_eq!(shard, 0);
                assert_eq!(ticket, doomed);
            }
            e => panic!("expected shard-lost, got {e:?}"),
        }
        assert!(waited < Duration::from_secs(10), "waiter must not hang");
        // The unclaimed doomed ticket resolves to the same fate later.
        match srv.wait(tickets[2], WAIT).unwrap_err() {
            ApiError::ShardLost { shard, ticket } => {
                assert_eq!(shard, 0);
                assert_eq!(ticket, tickets[2]);
            }
            e => panic!("expected shard-lost, got {e:?}"),
        }
        // Shard 1's work is untouched by the kill.
        assert_eq!(srv.wait(tickets[1], WAIT).unwrap().shard, 1);
        assert_eq!(srv.wait(tickets[3], WAIT).unwrap().shard, 1);
        // Quiescence: every accepted invocation has exactly one fate,
        // and the dead shard's parked timer items were dropped as
        // stale, not delivered to the rebuilt plane.
        let m = wait_membership(&srv, |m| {
            m.conserved_at_quiescence() && m.stale_drops >= 2
        });
        assert_eq!(m.accepted, 4);
        assert_eq!(m.completed, 2);
        assert_eq!(m.failed, 2);
        assert!(m.conserved_at_quiescence(), "fate conservation: {m:?}");
        assert!(m.stale_drops >= 2, "stale epoch items must drop: {m:?}");
    }

    #[test]
    fn drain_stops_routing_and_join_restores_it() {
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.001).unwrap();
        let m = srv.drain(1).unwrap();
        assert_eq!(m.shards[1].health, ShardHealth::Draining);
        assert_eq!(m.shards[1].epoch, 0, "drain does not bump the epoch");
        for _ in 0..4 {
            let t = srv.submit("isoneural-0").unwrap();
            assert_eq!(srv.wait(t, WAIT).unwrap().shard, 0);
        }
        // The other shard is now the last live one: protected.
        let e = srv.drain(0).unwrap_err();
        assert_eq!(e.code(), "bad-request");
        assert_eq!(srv.kill(0).unwrap_err().code(), "bad-request");
        // Rejoin: round-robin reaches both shards again.
        srv.join(1).unwrap();
        let shards: std::collections::HashSet<usize> = (0..4)
            .map(|_| {
                let t = srv.submit("isoneural-0").unwrap();
                srv.wait(t, WAIT).unwrap().shard
            })
            .collect();
        assert_eq!(shards.len(), 2, "rejoined shard must serve again");
        let m = wait_membership(&srv, MembershipInfo::conserved_at_quiescence);
        assert_eq!(m.accepted, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn membership_counts_rejections_and_validates_shards() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let m = srv.membership().unwrap();
        assert_eq!(m.shards.len(), 1);
        assert_eq!(m.shards[0].health, ShardHealth::Up);
        assert_eq!((m.accepted, m.rejected), (0, 0));
        // Admission rejections are counted apart from accepted work.
        assert!(srv.submit("ghost").is_err());
        let m = srv.membership().unwrap();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.accepted, 0);
        // A single-plane server's only shard is its last live one.
        assert_eq!(srv.drain(0).unwrap_err().code(), "bad-request");
        assert_eq!(srv.kill(0).unwrap_err().code(), "bad-request");
        // Out-of-range shards are a client error on every verb.
        assert_eq!(srv.drain(7).unwrap_err().code(), "bad-request");
        assert_eq!(srv.join(7).unwrap_err().code(), "bad-request");
        assert_eq!(srv.kill(7).unwrap_err().code(), "bad-request");
        // Membership verbs work through cloneable handles too.
        assert!(srv.handle().membership().is_ok());
    }

    #[test]
    fn killed_shard_rejoins_cold_and_serves() {
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.001).unwrap();
        // Warm shard 0, then kill it (idle: nothing stranded).
        let t = srv.submit("isoneural-0").unwrap();
        assert_eq!(srv.wait(t, WAIT).unwrap().shard, 0);
        let m = srv.kill(0).unwrap();
        assert_eq!(m.failed, 0, "an idle kill strands nothing");
        srv.join(0).unwrap();
        // The rebuilt plane serves — cold again (warm pool discarded).
        let shards: Vec<usize> = (0..2)
            .map(|_| {
                let t = srv.submit("isoneural-0").unwrap();
                let o = srv.wait(t, WAIT).unwrap();
                if o.shard == 0 {
                    assert_eq!(o.start_kind, StartKind::Cold, "rebuilt plane is cold");
                }
                o.shard
            })
            .collect();
        assert!(shards.contains(&0), "rejoined shard must serve");
        let m = wait_membership(&srv, MembershipInfo::conserved_at_quiescence);
        assert!(m.conserved_at_quiescence(), "{m:?}");
    }

    #[test]
    fn frontend_trait_objects_serve_both_impls() {
        // The serving layer only sees `&dyn Frontend` — both frontends
        // must be usable through it.
        let server = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let cluster = RtCluster::new(
            workload(),
            ClusterConfig {
                n_shards: 1,
                plane: fast_cfg(),
                ..Default::default()
            },
            None,
            0.001,
        )
        .unwrap();
        let fronts: [&dyn Frontend; 2] = [&server, &cluster];
        for f in fronts {
            let o = f.invoke("isoneural-0", WAIT).unwrap();
            assert_eq!(o.func, "isoneural-0");
            assert_eq!(f.stats().invocations, 1);
        }
    }

    #[test]
    fn executor_thread_count_is_config_not_load() {
        // shards × workers + 1 timer, fixed at construction...
        let srv = RtServer::with_workers(workload(), fast_cfg(), None, 0.0005, 3).unwrap();
        assert_eq!(srv.exec_threads(), 3 + 1);
        // ...and unchanged by a burst (the 1k-invoke version lives in
        // rust/tests/wire_protocol.rs; this pins the unit invariant).
        let tickets: Vec<Ticket> = (0..64)
            .map(|_| srv.submit("isoneural-0").unwrap())
            .collect();
        for t in tickets {
            srv.wait(t, WAIT).unwrap();
        }
        assert_eq!(srv.exec_threads(), 3 + 1);
        assert_eq!(srv.stats().invocations, 64);
    }

    #[test]
    fn idle_monitor_parks_without_tick_lock_traffic() {
        // 20 ms monitor period: an idle server must not tick at all.
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        thread::sleep(Duration::from_millis(200));
        assert_eq!(srv.monitor_ticks(), 0, "idle monitor must stay parked");
        // Work wakes the monitor; ticks flow while the shard is busy.
        let t = srv.submit("fft-0").unwrap();
        srv.wait(t, WAIT).unwrap();
        // After the shard drains, at most one trailing tick can land;
        // then the count must freeze again.
        thread::sleep(Duration::from_millis(200));
        let settled = srv.monitor_ticks();
        thread::sleep(Duration::from_millis(300));
        assert_eq!(
            srv.monitor_ticks(),
            settled,
            "drained shard's monitor must re-park"
        );
    }

    #[test]
    fn stats_fast_path_matches_plane_recorders() {
        // The O(1) stats aggregates must agree with the ground truth in
        // the per-shard recorders once the server quiesces.
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.0005).unwrap();
        let tickets: Vec<Ticket> = (0..10)
            .map(|i| srv.submit(["isoneural-0", "fft-0"][i % 2]).unwrap())
            .collect();
        for t in tickets {
            srv.wait(t, WAIT).unwrap();
        }
        let s = srv.stats();
        assert_eq!(s.invocations, 10);
        assert_eq!(s.pending, 0);
        assert_eq!(s.in_flight, 0);
        let (mut n, mut lat_sum, mut cold_sum) = (0usize, 0.0f64, 0.0f64);
        for st in srv.inner.shards.iter() {
            let plane = st.plane.lock().unwrap();
            let k = plane.recorder.len();
            n += k;
            lat_sum += plane.recorder.weighted_avg_latency_s() * k as f64;
            cold_sum += plane.recorder.cold_ratio() * k as f64;
        }
        assert_eq!(n, 10);
        assert!((s.mean_latency_ms - lat_sum / n as f64 * 1e3).abs() < 1e-6);
        assert!((s.cold_ratio - cold_sum / n as f64).abs() < 1e-9);
    }

    // --- failure model (see module docs) ------------------------------

    #[test]
    fn transient_fault_retries_to_completion_exactly_once() {
        // Every attempt faults until the cap (1): the first attempt
        // fails, the retry completes, and the submitter's ticket is
        // fulfilled exactly once.
        let cfg = PlaneConfig {
            monitor_period: 20 * MS,
            faults: Some(FaultConfig {
                seed: 7,
                transient_rate: 1.0,
                max_faults: 1,
                retry_budget: 3,
                ..Default::default()
            }),
            ..fast_cfg()
        };
        let srv = RtServer::new(workload(), cfg, None, 0.001).unwrap();
        let t = srv.submit("isoneural-0").unwrap();
        let o = srv.wait(t, WAIT).unwrap();
        assert_eq!(o.ticket, t);
        assert_eq!(srv.stats().invocations, 1, "one completion, not two");
        let m = wait_membership(&srv, MembershipInfo::conserved_at_quiescence);
        assert_eq!((m.accepted, m.completed, m.failed), (1, 1, 0));
        let fs = srv.inner.shards[0].plane.lock().unwrap().fault_stats();
        assert_eq!(fs.faults_transient, 1);
        assert_eq!(fs.retries, 1);
    }

    #[test]
    fn exhausted_retry_budget_fails_the_ticket_with_exec_failed() {
        // Unbounded faulting with a 2-attempt budget: the waiter wakes
        // with the structured error, and fate conservation counts the
        // invocation as failed — never completed.
        let cfg = PlaneConfig {
            monitor_period: 20 * MS,
            faults: Some(FaultConfig {
                seed: 7,
                transient_rate: 1.0,
                retry_budget: 2,
                ..Default::default()
            }),
            ..fast_cfg()
        };
        let srv = RtServer::new(workload(), cfg, None, 0.001).unwrap();
        let t = srv.submit("isoneural-0").unwrap();
        match srv.wait(t, WAIT).unwrap_err() {
            ApiError::ExecFailed { ticket, attempts } => {
                assert_eq!(ticket, t);
                assert_eq!(attempts, 2);
            }
            e => panic!("expected exec-failed, got {e:?}"),
        }
        assert_eq!(srv.stats().invocations, 0);
        let m = wait_membership(&srv, MembershipInfo::conserved_at_quiescence);
        assert_eq!((m.accepted, m.completed, m.failed), (1, 0, 1));
        assert!(m.conserved_at_quiescence(), "{m:?}");
    }

    #[test]
    fn poison_function_trips_the_breaker_into_quarantine() {
        let cfg = PlaneConfig {
            monitor_period: 20 * MS,
            faults: Some(FaultConfig {
                seed: 3,
                poison: vec![(FuncId(1), 1.0)], // fft-0
                retry_budget: 1,
                breaker: Some(BreakerConfig {
                    window: 4,
                    trip_threshold: 0.5,
                    min_samples: 2,
                    cooldown: 3600 * SEC,
                    probes: 1,
                }),
                ..Default::default()
            }),
            ..fast_cfg()
        };
        let srv = RtServer::new(workload(), cfg, None, 0.001).unwrap();
        // Two observed failures trip the breaker...
        for _ in 0..2 {
            let t = srv.submit("fft-0").unwrap();
            assert_eq!(srv.wait(t, WAIT).unwrap_err().code(), "exec-failed");
        }
        // ...so the third submit is refused before entering the plane.
        match srv.submit("fft-0").unwrap_err() {
            ApiError::Quarantined {
                func,
                retry_after_ms,
            } => {
                assert_eq!(func, "fft-0");
                assert!(retry_after_ms > 0, "cooldown hint must be real");
            }
            e => panic!("expected quarantined, got {e:?}"),
        }
        // Quarantine is a rejection, not a fate; healthy tenants flow.
        let m = srv.membership().unwrap();
        assert_eq!(m.rejected, 1);
        let t = srv.submit("isoneural-0").unwrap();
        srv.wait(t, WAIT).unwrap();
    }

    #[test]
    fn shed_rejects_with_structured_retry_hint() {
        // A microscopic deadline: any backlog at all predicts a miss,
        // so the second submit is shed with the configured hint.
        let cfg = PlaneConfig {
            monitor_period: 20 * MS,
            faults: Some(FaultConfig {
                shed: Some(ShedConfig {
                    deadline_s: 1e-6,
                    retry_after_ms: 123,
                    ..Default::default()
                }),
                ..Default::default()
            }),
            ..fast_cfg()
        };
        let srv = RtServer::new(workload(), cfg, None, 0.01).unwrap();
        let t = srv.submit("fft-0").unwrap();
        match srv.submit("fft-0").unwrap_err() {
            ApiError::Overloaded { retry_after_ms, .. } => assert_eq!(retry_after_ms, 123),
            e => panic!("expected overloaded, got {e:?}"),
        }
        let m = srv.membership().unwrap();
        assert_eq!((m.accepted, m.rejected), (1, 1));
        srv.wait(t, WAIT).unwrap();
    }
}
