//! Real-traffic serving: wall-clock frontends around the control plane,
//! speaking protocol v1 ([`crate::api`]) over TCP.
//!
//! Two [`Frontend`] implementations share one engine:
//!
//! * [`RtServer`] — a single [`ControlPlane`] (the original per-server
//!   driver, now behind the typed API).
//! * [`RtCluster`] — N independent [`ControlPlane`] shards behind a
//!   [`crate::cluster::Router`] (StickyCh / least-loaded / ...), the
//!   wall-clock sibling of [`crate::sim::replay_cluster`]: per-shard
//!   monitor threads, capacity-weighted routing on live queue depths,
//!   and completion feedback through each shard's own plane. This is
//!   the ROADMAP's "RPC front end so `serve` can run the router for
//!   real traffic".
//!
//! Python never runs here — dispatched functions execute their AOT HLO
//! artifact on a dedicated PJRT executor thread (the CPU PJRT client is
//! the testbed's stand-in for the GPU; see DESIGN.md §1). Modeled
//! control-plane delays (cold boots, prefetch blocking) are slept at a
//! configurable time scale so demos finish quickly.
//!
//! # Protocol
//!
//! One JSON document per line, both directions, after a `hello`
//! version handshake (see [`crate::api::wire`] for the full grammar):
//!
//! ```text
//! > {"cmd":"hello","v":1}
//! < {"ok":true,"type":"hello","proto":1,"server":"rt-cluster"}
//! > {"cmd":"invoke","func":"fft-0","mode":"sync","deadline_ms":5000}
//! < {"ok":true,"type":"done","ticket":0,"func":"fft-0","shard":1,
//!    "gpu":0,"start":"cold","latency_ms":412.0,"exec_ms":9.1}
//! > {"cmd":"invoke","func":"fft-0","mode":"async"}
//! < {"ok":true,"type":"ticket","ticket":1}
//! > {"cmd":"wait","ticket":1}
//! < {"ok":true,"type":"done", ...}
//! > {"cmd":"stats"}
//! < {"ok":true,"type":"stats","invocations":2, ...}
//! ```
//!
//! Errors are structured (`{"ok":false,"error":"unknown-function",...}`;
//! taxonomy in [`crate::api::ApiError`]). The pre-v1 word protocol —
//! `invoke <fn>` / `stats` / `quit` with `ok ...`/`err ...` replies —
//! survives as legacy aliases on the same port: any line not starting
//! with `{` is parsed as a legacy command.
//!
//! # Ownership: handles vs the shutdown guard
//!
//! All serving state lives in one shared `Inner`. [`RtHandle`] is a
//! cloneable `Arc` view of it — connections, the accept loop, and
//! embedders hold handles, and dropping a handle is inert. The
//! constructor-returned guard ([`RtServer`]/[`RtCluster`]) is the
//! *single* owner of shutdown: only its `shutdown()`/`Drop` stops the
//! monitor threads and the accept loop. (The previous design cloned the
//! guard itself into every connection, so the first client disconnect
//! ran `Drop::drop → shutdown()` and silently killed the server for
//! everyone — the regression test lives in `rust/tests/wire_protocol.rs`.)

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::api::types::{
    ApiError, DescribeInfo, InvokeOutcome, StatsSnapshot, Ticket, PROTOCOL_VERSION,
};
use crate::api::Frontend;
use crate::clock::{Clock, RealClock};
use crate::cluster::{ClusterConfig, Router, RouterKind, ShardLoad};
use crate::plane::{ControlPlane, Dispatch, PlaneConfig};
use crate::runtime::PjrtRuntime;
use crate::types::{to_secs, InvocationId, Nanos};
use crate::workload::Workload;

/// Job sent to the PJRT executor thread.
struct ExecJob {
    artifact: String,
    reply: Sender<Duration>,
}

/// Completion bookkeeping for one accepted invocation.
enum TicketEntry {
    /// Still running; waiters are woken (all of them) on completion.
    Pending { waiters: Vec<Sender<InvokeOutcome>> },
    /// Completed but not yet claimed by `wait`/`poll`.
    Done(InvokeOutcome),
}

/// Ticket registry with a bound on completed-but-unclaimed entries, so
/// fire-and-forget async clients (or crashed ones) cannot grow the
/// table without limit on a long-running server: beyond
/// [`TicketTable::DEFAULT_MAX_DONE`] unclaimed completions, the oldest
/// are evicted (a later `wait` on one gets `unknown-ticket`, exactly as
/// if it had been claimed).
struct TicketTable {
    entries: HashMap<u64, TicketEntry>,
    /// Completion order of `Done` entries; may contain stale ids of
    /// since-claimed tickets (filtered during eviction — ids are never
    /// reused, so staleness is unambiguous).
    done_order: VecDeque<u64>,
    /// Live `Done` entries (kept ≤ `max_done`).
    done_count: usize,
    max_done: usize,
}

impl TicketTable {
    /// Unclaimed completions retained before the oldest are dropped.
    const DEFAULT_MAX_DONE: usize = 1 << 16;

    fn new() -> Self {
        Self {
            entries: HashMap::new(),
            done_order: VecDeque::new(),
            done_count: 0,
            max_done: Self::DEFAULT_MAX_DONE,
        }
    }

    fn insert_pending(&mut self, id: u64) {
        self.entries.insert(
            id,
            TicketEntry::Pending {
                waiters: Vec::new(),
            },
        );
    }

    /// Remove an entry, keeping the unclaimed-done count in sync.
    fn remove(&mut self, id: u64) -> Option<TicketEntry> {
        let entry = self.entries.remove(&id);
        if matches!(entry, Some(TicketEntry::Done(_))) {
            self.done_count -= 1;
        }
        entry
    }

    /// Mark `id` done, returning the displaced entry (the waiters to
    /// wake). Evicts the oldest unclaimed completions over the bound.
    fn complete(&mut self, id: u64, outcome: InvokeOutcome) -> Option<TicketEntry> {
        let prev = self.entries.insert(id, TicketEntry::Done(outcome));
        if !matches!(prev, Some(TicketEntry::Done(_))) {
            self.done_count += 1;
        }
        self.done_order.push_back(id);
        while self.done_count > self.max_done {
            let Some(old) = self.done_order.pop_front() else {
                break;
            };
            if matches!(self.entries.get(&old), Some(TicketEntry::Done(_))) {
                self.entries.remove(&old);
                self.done_count -= 1;
            }
        }
        // The order queue accumulates stale ids of promptly-claimed
        // tickets; compact it once it doubles past the live bound
        // (amortized O(1) per completion, keeps both structures bounded).
        if self.done_order.len() > self.max_done.saturating_mul(2).max(64) {
            let entries = &self.entries;
            self.done_order
                .retain(|id| matches!(entries.get(id), Some(TicketEntry::Done(_))));
        }
        prev
    }
}

/// Shared serving state: shards, router, tickets, executor.
struct Inner {
    /// Frontend kind for `describe`: `rt-server` or `rt-cluster`.
    kind: &'static str,
    router_name: &'static str,
    shards: Vec<Mutex<ControlPlane>>,
    /// Routing decision for each arrival (a single-shard server uses a
    /// trivial ring that always answers 0).
    router: Mutex<Box<dyn Router>>,
    /// Per-shard fleet capacity (V100-equivalents) for [`ShardLoad`].
    capacities: Vec<f64>,
    clock: RealClock,
    /// Modeled-delay scale: 1 virtual second sleeps `scale` real seconds.
    scale: f64,
    exec_tx: Option<Sender<ExecJob>>,
    /// `(shard, shard-local invocation id) → (ticket, function name)`,
    /// registered under the shard's plane lock at submit time so a
    /// racing completion can never observe an unmapped invocation.
    inv_tickets: Mutex<HashMap<(usize, InvocationId), (Ticket, String)>>,
    tickets: Mutex<TicketTable>,
    /// Lock-free admission lookup: registered name *and* class name →
    /// (id, registered name), precomputed from the workload (identical
    /// on every shard) so submits never scan under a plane lock.
    func_index: HashMap<String, (crate::types::FuncId, String)>,
    next_ticket: AtomicU64,
    /// Admission bound on total queued work (`usize::MAX` = unlimited).
    max_pending: AtomicUsize,
    running: AtomicBool,
}

impl Inner {
    fn loads(&self) -> Vec<ShardLoad> {
        self.shards
            .iter()
            .enumerate()
            .map(|(s, p)| {
                let p = p.lock().unwrap();
                ShardLoad {
                    pending: p.pending(),
                    in_flight: p.in_flight(),
                    capacity: self.capacities[s],
                }
            })
            .collect()
    }
}

/// Cloneable, shutdown-free view of a running frontend. Connections and
/// embedders hold these; only the constructor-returned guard can stop
/// the server.
#[derive(Clone)]
pub struct RtHandle {
    inner: Arc<Inner>,
}

// ---------------------------------------------------------------------
// Frontend implementation over Inner.
// ---------------------------------------------------------------------

fn describe_inner(inner: &Arc<Inner>) -> DescribeInfo {
    let plane = inner.shards[0].lock().unwrap();
    DescribeInfo {
        proto: PROTOCOL_VERSION,
        server: inner.kind.to_string(),
        policy: plane.policy_name().to_string(),
        shards: inner.shards.len(),
        router: inner.router_name.to_string(),
        functions: plane.workload().funcs.iter().map(|f| f.name.clone()).collect(),
    }
}

fn submit_inner(inner: &Arc<Inner>, name: &str) -> Result<Ticket, ApiError> {
    if !inner.running.load(Ordering::SeqCst) {
        return Err(ApiError::ShuttingDown);
    }
    let Some((func, reg_name)) = inner.func_index.get(name).cloned() else {
        return Err(ApiError::UnknownFunction {
            name: name.to_string(),
        });
    };
    // Admission control: bound total queued work before routing.
    let loads = inner.loads();
    let pending: usize = loads.iter().map(|l| l.pending).sum();
    let limit = inner.max_pending.load(Ordering::SeqCst);
    if pending >= limit {
        return Err(ApiError::Overloaded { pending, limit });
    }
    let shard = inner.router.lock().unwrap().route(func, &loads);
    debug_assert!(shard < inner.shards.len(), "router out of range");
    let ticket = Ticket(inner.next_ticket.fetch_add(1, Ordering::SeqCst));
    inner.tickets.lock().unwrap().insert_pending(ticket.0);
    let ds = {
        let mut plane = inner.shards[shard].lock().unwrap();
        let now = inner.clock.now();
        let (inv, ds) = plane.on_arrival(func, now);
        // Map under the plane lock (see Inner::inv_tickets).
        inner
            .inv_tickets
            .lock()
            .unwrap()
            .insert((shard, inv), (ticket, reg_name));
        ds
    };
    handle_dispatches(inner, shard, ds);
    Ok(ticket)
}

fn wait_inner(
    inner: &Arc<Inner>,
    ticket: Ticket,
    deadline: Option<Duration>,
) -> Result<InvokeOutcome, ApiError> {
    let rx = {
        let mut tickets = inner.tickets.lock().unwrap();
        match tickets.remove(ticket.0) {
            None => return Err(ApiError::UnknownTicket { ticket }),
            // Already completed: claiming removes the entry.
            Some(TicketEntry::Done(o)) => return Ok(o),
            Some(TicketEntry::Pending { mut waiters }) => {
                let (tx, rx) = channel();
                waiters.push(tx);
                tickets
                    .entries
                    .insert(ticket.0, TicketEntry::Pending { waiters });
                rx
            }
        }
    };
    let outcome = match deadline {
        // Expired: report the ticket so the (possibly sync-invoking)
        // client can still redeem the run-to-completion invocation.
        Some(dl) => rx.recv_timeout(dl).map_err(|_| ApiError::DeadlineExceeded {
            waited_ms: dl.as_millis() as u64,
            ticket: Some(ticket),
        })?,
        // Sender-side drop (process teardown) surfaces as shutdown.
        None => rx.recv().map_err(|_| ApiError::ShuttingDown)?,
    };
    // Claimed: reclaim the entry (concurrent waiters were all woken by
    // the same fulfillment; whichever removes second is a no-op).
    inner.tickets.lock().unwrap().remove(ticket.0);
    Ok(outcome)
}

fn poll_inner(inner: &Arc<Inner>, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
    let mut tickets = inner.tickets.lock().unwrap();
    match tickets.remove(ticket.0) {
        None => Err(ApiError::UnknownTicket { ticket }),
        // Done: claiming removes the entry, like a successful wait.
        Some(TicketEntry::Done(o)) => Ok(Some(o)),
        Some(pending @ TicketEntry::Pending { .. }) => {
            tickets.entries.insert(ticket.0, pending);
            Ok(None)
        }
    }
}

fn stats_inner(inner: &Arc<Inner>) -> StatsSnapshot {
    let mut s = StatsSnapshot::default();
    let mut lat_sum = 0.0;
    let mut cold_sum = 0.0;
    for shard in &inner.shards {
        let plane = shard.lock().unwrap();
        let n = plane.recorder.len();
        lat_sum += plane.recorder.weighted_avg_latency_s() * n as f64;
        cold_sum += plane.recorder.cold_ratio() * n as f64;
        s.invocations += n;
        s.pending += plane.pending();
        s.in_flight += plane.in_flight();
    }
    if s.invocations > 0 {
        s.mean_latency_ms = lat_sum / s.invocations as f64 * 1e3;
        s.cold_ratio = cold_sum / s.invocations as f64;
    }
    s
}

/// Single copy of the [`Frontend`] wiring, stamped onto every type that
/// exposes the shared `Inner` (the handle and both guards — identical
/// behavior by construction). `shutdown` only flips admission; joining
/// the monitor threads needs a guard's own `stop()` or `Drop`.
macro_rules! impl_frontend_via_inner {
    ($ty:ty) => {
        impl Frontend for $ty {
            fn describe(&self) -> DescribeInfo {
                describe_inner(&self.inner)
            }
            fn submit(&self, func: &str) -> Result<Ticket, ApiError> {
                submit_inner(&self.inner, func)
            }
            fn wait(
                &self,
                ticket: Ticket,
                deadline: Option<Duration>,
            ) -> Result<InvokeOutcome, ApiError> {
                wait_inner(&self.inner, ticket, deadline)
            }
            fn poll(&self, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
                poll_inner(&self.inner, ticket)
            }
            fn stats(&self) -> StatsSnapshot {
                stats_inner(&self.inner)
            }
            fn shutdown(&self) {
                self.inner.running.store(false, Ordering::SeqCst);
            }
        }
    };
}

impl_frontend_via_inner!(RtHandle);
impl_frontend_via_inner!(RtServer);
impl_frontend_via_inner!(RtCluster);

/// Single copy of the shutdown-guard surface, stamped onto both guards
/// (`RtServer`, `RtCluster`): handle/serve/backpressure plus the
/// stop-and-join that only a guard — never a dropped connection handle
/// — may trigger.
macro_rules! impl_guard {
    ($ty:ty) => {
        impl $ty {
            /// Cloneable, shutdown-free view for connections and embedding.
            pub fn handle(&self) -> RtHandle {
                RtHandle {
                    inner: Arc::clone(&self.inner),
                }
            }

            /// Serve the protocol on `addr` (port 0 picks a free one).
            pub fn serve(&self, addr: &str) -> anyhow::Result<std::net::SocketAddr> {
                serve_on(self.handle(), addr)
            }

            /// Backpressure bound: reject (`overloaded`) when total
            /// queued work is at/above `limit` at submit time.
            pub fn set_max_pending(&self, limit: usize) {
                self.inner.max_pending.store(limit, Ordering::SeqCst);
            }

            /// Stop admissions and join the monitor thread(s).
            /// Idempotent; also runs on `Drop`. Only this guard stops
            /// the server — dropped connection handles never do.
            pub fn stop(&self) {
                self.inner.running.store(false, Ordering::SeqCst);
                for h in self.monitors.lock().unwrap().drain(..) {
                    let _ = h.join();
                }
            }
        }

        impl Drop for $ty {
            fn drop(&mut self) {
                self.stop();
            }
        }
    };
}

// ---------------------------------------------------------------------
// Construction + background threads.
// ---------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn build_inner(
    kind: &'static str,
    router_name: &'static str,
    workload: Workload,
    plane_cfgs: Vec<PlaneConfig>,
    router: Box<dyn Router>,
    capacities: Vec<f64>,
    artifacts_dir: Option<&std::path::Path>,
    scale: f64,
) -> anyhow::Result<Arc<Inner>> {
    assert!(scale > 0.0);
    let exec_tx = match artifacts_dir {
        Some(dir) => Some(spawn_executor(dir, &workload)?),
        None => None,
    };
    // Admission index, first match wins like the old linear scan:
    // registered name (unique) and class name (first copy).
    let mut func_index = HashMap::new();
    for f in &workload.funcs {
        func_index
            .entry(f.name.clone())
            .or_insert((f.id, f.name.clone()));
        func_index
            .entry(f.class.name.to_string())
            .or_insert((f.id, f.name.clone()));
    }
    let shards = plane_cfgs
        .into_iter()
        .map(|cfg| Mutex::new(ControlPlane::new(workload.clone(), cfg)))
        .collect();
    Ok(Arc::new(Inner {
        kind,
        router_name,
        shards,
        router: Mutex::new(router),
        capacities,
        clock: RealClock::new(),
        scale,
        exec_tx,
        inv_tickets: Mutex::new(HashMap::new()),
        tickets: Mutex::new(TicketTable::new()),
        func_index,
        next_ticket: AtomicU64::new(0),
        max_pending: AtomicUsize::new(usize::MAX),
        running: AtomicBool::new(true),
    }))
}

/// Monitor thread for one shard: scaled-free 200 ms-class ticks (the
/// shard's own `monitor_period`, real time), exactly like the paper's
/// NVML poller — utilization sampling, dynamic D, TTL expiry.
fn spawn_monitor(inner: &Arc<Inner>, shard: usize) -> thread::JoinHandle<()> {
    let mon = Arc::clone(inner);
    thread::spawn(move || {
        let period =
            Duration::from_nanos(mon.shards[shard].lock().unwrap().cfg.monitor_period);
        while mon.running.load(Ordering::SeqCst) {
            thread::sleep(period);
            let now = mon.clock.now();
            let ds = mon.shards[shard].lock().unwrap().on_monitor_tick(now);
            handle_dispatches(&mon, shard, ds);
        }
    })
}

/// PJRT executor thread: owns the (non-Send) runtime; executes one
/// artifact at a time. The serialization is harmless — the CPU PJRT
/// client is itself internally parallel and stands in for one GPU.
fn spawn_executor(
    dir: &std::path::Path,
    workload: &Workload,
) -> anyhow::Result<Sender<ExecJob>> {
    let (tx, rx): (Sender<ExecJob>, Receiver<ExecJob>) = channel();
    let dir = dir.to_path_buf();
    let names: Vec<String> = {
        let mut v: Vec<String> = workload
            .funcs
            .iter()
            .map(|f| f.class.name.to_string())
            .collect();
        v.sort();
        v.dedup();
        v
    };
    let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
    thread::spawn(move || {
        let mut rt = match PjrtRuntime::new(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        for name in &names {
            if let Err(e) = rt.load_function(name) {
                let _ = ready_tx.send(Err(e));
                return;
            }
        }
        let _ = ready_tx.send(Ok(()));
        while let Ok(job) = rx.recv() {
            let t0 = std::time::Instant::now();
            let _ = rt.execute(&job.artifact);
            let _ = job.reply.send(t0.elapsed());
        }
    });
    ready_rx.recv().expect("executor thread died")?;
    Ok(tx)
}

/// Run each dispatch on a worker thread: sleep the scaled pre-exec
/// delays, execute (PJRT or modeled sleep), then complete and fulfill
/// the submitter's ticket.
fn handle_dispatches(inner: &Arc<Inner>, shard: usize, ds: Vec<Dispatch>) {
    for d in ds {
        let inner = Arc::clone(inner);
        thread::spawn(move || run_dispatch(&inner, shard, d));
    }
}

fn run_dispatch(inner: &Arc<Inner>, shard: usize, d: Dispatch) {
    let scale = inner.scale;
    let sleep_scaled = |ns: Nanos| {
        if ns > 0 {
            thread::sleep(Duration::from_secs_f64(to_secs(ns) * scale));
        }
    };
    // Cold boot + shim blocking (modeled, scaled).
    sleep_scaled(d.exec_start.saturating_sub(d.at));
    let exec_t0 = inner.clock.now();

    // Service: real PJRT execution, or the modeled time scaled.
    let class_name = {
        let mut plane = inner.shards[shard].lock().unwrap();
        // Exact utilization-integral touch at the wall-clock exec start
        // (the sim engine's Touch event, live).
        plane.touch(exec_t0);
        plane.workload().func(d.func).class.name.to_string()
    };
    if let Some(tx) = &inner.exec_tx {
        let (rtx, rrx) = channel();
        if tx
            .send(ExecJob {
                artifact: class_name,
                reply: rtx,
            })
            .is_ok()
        {
            let _ = rrx.recv();
        }
    } else {
        sleep_scaled(d.exec);
    }

    let now = inner.clock.now();
    let (rec, ds) = inner.shards[shard].lock().unwrap().on_complete(d.inv, now);
    // Completion matching: the plane hands back the completed
    // invocation's own record (not `records.last()`, which under
    // concurrent completions may belong to someone else).
    if let Some(rec) = rec {
        debug_assert_eq!(rec.inv, d.inv);
        let mapped = inner.inv_tickets.lock().unwrap().remove(&(shard, d.inv));
        if let Some((ticket, func_name)) = mapped {
            fulfill(
                inner,
                ticket,
                InvokeOutcome {
                    ticket,
                    func: func_name,
                    shard,
                    gpu: rec.gpu.0,
                    start_kind: rec.start_kind,
                    latency_ms: to_secs(rec.completed.saturating_sub(rec.arrived)) * 1e3,
                    exec_ms: to_secs(now.saturating_sub(exec_t0)) * 1e3,
                },
            );
        }
    }
    handle_dispatches(inner, shard, ds);
}

/// Mark a ticket done and wake every waiter blocked on it.
fn fulfill(inner: &Arc<Inner>, ticket: Ticket, outcome: InvokeOutcome) {
    let prev = inner
        .tickets
        .lock()
        .unwrap()
        .complete(ticket.0, outcome.clone());
    if let Some(TicketEntry::Pending { waiters }) = prev {
        for w in waiters {
            let _ = w.send(outcome.clone());
        }
    }
}

/// Accept loop on `addr`; every connection is served over a cloned
/// [`RtHandle`] (never the shutdown guard — see the module docs).
fn serve_on(handle: RtHandle, addr: &str) -> anyhow::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    thread::spawn(move || {
        for stream in listener.incoming() {
            if !handle.inner.running.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let conn = handle.clone();
            thread::spawn(move || crate::api::wire::serve_connection(&conn, stream));
        }
    });
    Ok(local)
}

// ---------------------------------------------------------------------
// RtServer: the single-plane frontend.
// ---------------------------------------------------------------------

/// Single-plane wall-clock frontend; the shutdown-owning guard.
/// Construct with [`RtServer::new`], serve TCP with [`RtServer::serve`],
/// embed via [`RtServer::handle`] or the [`Frontend`] impl.
pub struct RtServer {
    inner: Arc<Inner>,
    monitors: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl RtServer {
    /// `artifacts_dir`: load + compile HLO artifacts and execute them on
    /// dispatch (real execution). `None`: sleep the modeled service time
    /// instead (pure control-plane demo).
    pub fn new(
        workload: Workload,
        cfg: PlaneConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
    ) -> anyhow::Result<Self> {
        let capacities = vec![cfg.fleet_capacity()];
        // Trivial ring: every routing question answers shard 0.
        let router = RouterKind::RoundRobin.build(1, 1.0, 0, &capacities);
        let inner = build_inner(
            "rt-server",
            "single",
            workload,
            vec![cfg],
            router,
            capacities,
            artifacts_dir,
            scale,
        )?;
        let monitors = Mutex::new(vec![spawn_monitor(&inner, 0)]);
        Ok(Self { inner, monitors })
    }
}

impl_guard!(RtServer);

// ---------------------------------------------------------------------
// RtCluster: N shards behind a live router.
// ---------------------------------------------------------------------

/// Sharded wall-clock frontend: N independent control planes behind a
/// [`crate::cluster::Router`], serving real TCP traffic. The shutdown-
/// owning guard, like [`RtServer`].
pub struct RtCluster {
    inner: Arc<Inner>,
    monitors: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl RtCluster {
    /// Build `cfg.n_shards` planes (heterogeneous via
    /// [`ClusterConfig::shard_planes`]), the capacity-weighted router,
    /// and one monitor thread per shard.
    pub fn new(
        workload: Workload,
        cfg: ClusterConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
    ) -> anyhow::Result<Self> {
        assert!(cfg.n_shards >= 1, "cluster needs at least one shard");
        assert!(
            cfg.shard_planes.is_empty() || cfg.shard_planes.len() == cfg.n_shards,
            "shard_planes must be empty or hold one config per shard"
        );
        let capacities = cfg.shard_capacities();
        let router = cfg
            .router
            .build(cfg.n_shards, cfg.load_factor, cfg.seed, &capacities);
        let planes: Vec<PlaneConfig> =
            (0..cfg.n_shards).map(|s| cfg.plane_for(s).clone()).collect();
        let inner = build_inner(
            "rt-cluster",
            cfg.router.name(),
            workload,
            planes,
            router,
            capacities,
            artifacts_dir,
            scale,
        )?;
        let monitors = Mutex::new(
            (0..cfg.n_shards)
                .map(|s| spawn_monitor(&inner, s))
                .collect(),
        );
        Ok(Self { inner, monitors })
    }

    pub fn n_shards(&self) -> usize {
        self.inner.shards.len()
    }
}

impl_guard!(RtCluster);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{StartKind, MS};
    use crate::workload::catalog::by_name;

    fn workload() -> Workload {
        let mut w = Workload::default();
        w.register(by_name("isoneural").unwrap(), 0, 1.0);
        w.register(by_name("fft").unwrap(), 0, 1.0);
        w
    }

    fn fast_cfg() -> PlaneConfig {
        PlaneConfig {
            monitor_period: 20 * MS,
            ..Default::default()
        }
    }

    const WAIT: Option<Duration> = Some(Duration::from_secs(30));

    #[test]
    fn submit_completes_in_model_mode() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let ticket = srv.submit("isoneural-0").unwrap();
        let c = srv.wait(ticket, WAIT).unwrap();
        assert_eq!(c.ticket, ticket);
        assert_eq!(c.func, "isoneural-0");
        assert_eq!(c.shard, 0);
        assert_eq!(c.start_kind, StartKind::Cold);
        assert!(c.latency_ms > 0.0);
        let s = srv.stats();
        assert_eq!(s.invocations, 1);
        assert!(s.mean_latency_ms > 0.0);
        assert!((s.cold_ratio - 1.0).abs() < 1e-9);
        // Claimed tickets are reclaimed.
        assert_eq!(
            srv.wait(ticket, WAIT).unwrap_err().code(),
            "unknown-ticket"
        );
    }

    #[test]
    fn class_name_resolves_like_registered_name() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let t = srv.submit("fft").unwrap();
        assert_eq!(srv.wait(t, WAIT).unwrap().func, "fft-0");
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.0005).unwrap();
        let names = ["isoneural-0", "fft-0"];
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| srv.submit(names[i % 2]).unwrap())
            .collect();
        for t in tickets {
            srv.wait(t, WAIT).unwrap();
        }
        assert_eq!(srv.stats().invocations, 6);
    }

    #[test]
    fn poll_observes_pending_then_done() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.005).unwrap();
        let t = srv.submit("fft-0").unwrap();
        // fft's cold boot is seconds of model time — milliseconds here —
        // so the first poll observes it still running.
        assert_eq!(srv.poll(t).unwrap(), None);
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        let outcome = loop {
            if let Some(o) = srv.poll(t).unwrap() {
                break o;
            }
            assert!(std::time::Instant::now() < deadline, "poll never completed");
            thread::sleep(Duration::from_millis(2));
        };
        assert_eq!(outcome.ticket, t);
        // Consumed by the successful poll.
        assert_eq!(srv.poll(t).unwrap_err().code(), "unknown-ticket");
    }

    #[test]
    fn unknown_function_is_structured() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let err = srv.submit("ghost").unwrap_err();
        assert_eq!(err.code(), "unknown-function");
    }

    #[test]
    fn backpressure_rejects_overload_deterministically() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        srv.set_max_pending(1);
        // Default D=2 on one GPU: two dispatch immediately, the third
        // queues (pending=1), so the fourth submit hits the bound.
        let t1 = srv.submit("fft-0").unwrap();
        let t2 = srv.submit("fft-0").unwrap();
        let t3 = srv.submit("fft-0").unwrap();
        let err = srv.submit("fft-0").unwrap_err();
        assert_eq!(err.code(), "overloaded");
        for t in [t1, t2, t3] {
            srv.wait(t, WAIT).unwrap();
        }
    }

    #[test]
    fn shutdown_rejects_new_work_and_is_guard_owned() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let handle = srv.handle();
        // Dropping handles is inert — admission stays open.
        drop(handle.clone());
        assert!(handle.submit("isoneural-0").is_ok());
        srv.stop();
        assert_eq!(handle.submit("isoneural-0").unwrap_err().code(), "shutting-down");
        assert_eq!(srv.submit("isoneural-0").unwrap_err().code(), "shutting-down");
    }

    #[test]
    fn describe_reports_shape() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let d = srv.describe();
        assert_eq!(d.proto, PROTOCOL_VERSION);
        assert_eq!(d.server, "rt-server");
        assert_eq!(d.shards, 1);
        assert_eq!(d.router, "single");
        assert_eq!(d.policy, "mqfq-sticky");
        assert_eq!(d.functions, vec!["isoneural-0", "fft-0"]);
    }

    #[test]
    fn cluster_frontend_spreads_and_aggregates() {
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.001).unwrap();
        assert_eq!(srv.n_shards(), 2);
        let d = srv.describe();
        assert_eq!(d.server, "rt-cluster");
        assert_eq!(d.shards, 2);
        assert_eq!(d.router, "round-robin");
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| srv.submit("isoneural-0").unwrap())
            .collect();
        let shards: std::collections::HashSet<usize> = tickets
            .into_iter()
            .map(|t| srv.wait(t, WAIT).unwrap().shard)
            .collect();
        assert_eq!(shards.len(), 2, "round-robin must hit both shards");
        assert_eq!(srv.stats().invocations, 4);
    }

    #[test]
    fn cluster_sticky_keeps_a_function_home() {
        let cfg = ClusterConfig {
            n_shards: 4,
            router: RouterKind::StickyCh,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.0005).unwrap();
        let mut shards = std::collections::HashSet::new();
        for _ in 0..6 {
            let t = srv.submit("fft-0").unwrap();
            shards.insert(srv.wait(t, WAIT).unwrap().shard);
        }
        assert_eq!(shards.len(), 1, "light sticky load must stay home");
    }

    #[test]
    fn wait_deadline_trips_then_completion_is_recoverable() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.01).unwrap();
        // fft cold boot ≈ 2.4 s model time → ≈ 24 ms wall; 1 ms deadline
        // trips long before that.
        let t = srv.submit("fft-0").unwrap();
        let err = srv.wait(t, Some(Duration::from_millis(1))).unwrap_err();
        assert_eq!(err.code(), "deadline-exceeded");
        // Run-to-completion: the invocation still finishes and the
        // ticket stays redeemable.
        let o = srv.wait(t, WAIT).unwrap();
        assert_eq!(o.ticket, t);
        assert_eq!(srv.stats().invocations, 1);
    }

    #[test]
    fn unknown_ticket_rejected() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        assert_eq!(
            srv.wait(Ticket(999), WAIT).unwrap_err().code(),
            "unknown-ticket"
        );
        assert_eq!(srv.poll(Ticket(999)).unwrap_err().code(), "unknown-ticket");
    }

    #[test]
    fn ticket_table_bounds_unclaimed_completions() {
        let outcome = |n: u64| InvokeOutcome {
            ticket: Ticket(n),
            func: "f".into(),
            shard: 0,
            gpu: 0,
            start_kind: StartKind::Cold,
            latency_ms: 1.0,
            exec_ms: 1.0,
        };
        let mut t = TicketTable::new();
        t.max_done = 2;
        for id in 0..5 {
            t.insert_pending(id);
            t.complete(id, outcome(id));
        }
        // Oldest unclaimed completions evicted down to the bound.
        assert_eq!(t.done_count, 2);
        assert!(t.remove(0).is_none());
        assert!(t.remove(1).is_none());
        assert!(t.remove(2).is_none());
        assert!(matches!(t.remove(3), Some(TicketEntry::Done(_))));
        assert!(matches!(t.remove(4), Some(TicketEntry::Done(_))));
        assert_eq!(t.done_count, 0);
        // Promptly-claimed tickets leave stale order ids behind; the
        // compaction keeps both structures bounded.
        for id in 5..500 {
            t.insert_pending(id);
            t.complete(id, outcome(id));
            assert!(matches!(t.remove(id), Some(TicketEntry::Done(_))));
        }
        assert!(t.entries.is_empty());
        assert_eq!(t.done_count, 0);
        assert!(t.done_order.len() <= t.max_done.saturating_mul(2).max(64) + 1);
    }

    #[test]
    fn frontend_trait_objects_serve_both_impls() {
        // The serving layer only sees `&dyn Frontend` — both frontends
        // must be usable through it.
        let server = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let cluster = RtCluster::new(
            workload(),
            ClusterConfig {
                n_shards: 1,
                plane: fast_cfg(),
                ..Default::default()
            },
            None,
            0.001,
        )
        .unwrap();
        let fronts: [&dyn Frontend; 2] = [&server, &cluster];
        for f in fronts {
            let o = f.invoke("isoneural-0", WAIT).unwrap();
            assert_eq!(o.func, "isoneural-0");
            assert_eq!(f.stats().invocations, 1);
        }
    }
}
