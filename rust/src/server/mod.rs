//! Real-traffic serving: wall-clock frontends around the control plane,
//! speaking protocol v1 ([`crate::api`]) over TCP.
//!
//! Two [`Frontend`] implementations share one engine:
//!
//! * [`RtServer`] — a single [`ControlPlane`] (the original per-server
//!   driver, now behind the typed API).
//! * [`RtCluster`] — N independent [`ControlPlane`] shards behind a
//!   [`crate::cluster::Router`] (StickyCh / least-loaded / ...), the
//!   wall-clock sibling of [`crate::sim::replay_cluster`].
//!
//! Python never runs here — dispatched functions execute their AOT HLO
//! artifact on a dedicated PJRT executor thread (the CPU PJRT client is
//! the testbed's stand-in for the GPU; see DESIGN.md §1). Modeled
//! control-plane delays (cold boots, prefetch blocking) are slept at a
//! configurable time scale so demos finish quickly.
//!
//! # Protocol
//!
//! One JSON document per line, both directions, after a `hello`
//! version handshake (see [`crate::api::wire`] for the full grammar):
//!
//! ```text
//! > {"cmd":"hello","v":1}
//! < {"ok":true,"type":"hello","proto":1,"server":"rt-cluster"}
//! > {"cmd":"invoke","func":"fft-0","mode":"sync","deadline_ms":5000}
//! < {"ok":true,"type":"done","ticket":0,"func":"fft-0","shard":1,
//!    "gpu":0,"start":"cold","latency_ms":412.0,"exec_ms":9.1}
//! > {"cmd":"invoke","func":"fft-0","mode":"async"}
//! < {"ok":true,"type":"ticket","ticket":1}
//! > {"cmd":"wait","ticket":1}
//! < {"ok":true,"type":"done", ...}
//! > {"cmd":"stats"}
//! < {"ok":true,"type":"stats","invocations":2, ...}
//! ```
//!
//! Errors are structured (`{"ok":false,"error":"unknown-function",...}`;
//! taxonomy in [`crate::api::ApiError`]). The pre-v1 word protocol —
//! `invoke <fn>` / `stats` / `quit` with `ok ...`/`err ...` replies —
//! survives as legacy aliases on the same port: any line not starting
//! with `{` is parsed as a legacy command.
//!
//! # Threading model: fixed pools, a timer wheel, and no per-request spawns
//!
//! The serving engine's thread count is a function of *configuration*,
//! never of offered load:
//!
//! * **One timer thread** owns a binary-heap timer wheel of pending
//!   wall-clock events — each dispatch's `exec_start` instant (cold
//!   boot + prefetch blocking, scaled) and, in model mode, its
//!   completion instant. When an event comes due the timer hands it to
//!   the owning shard's worker pool and goes back to sleep until the
//!   next deadline; it never touches a plane lock itself.
//! * **A fixed worker pool per shard** ([`DEFAULT_WORKERS`] threads
//!   unless overridden via `with_workers`) drains the shard's work
//!   queue: exec-start touches, PJRT execution (workers block on the
//!   executor, bounding concurrent jobs), completion bookkeeping, and
//!   ticket fulfillment. Model-mode workers never sleep — modeled
//!   service time is a timer event, so a worker's cost per invocation
//!   is bookkeeping only.
//! * **One monitor thread per shard** drives the paper's 200 ms-class
//!   NVML poll (utilization sampling, dynamic D, TTL expiry). Idle
//!   shards park on a condvar instead of ticking: the monitor only
//!   sleeps-and-locks while the shard has work, and a submit to an
//!   idle shard wakes it. An idle server generates *zero* tick-driven
//!   plane-lock traffic (asserted by test via [`RtServer::monitor_ticks`]).
//! * **One accept thread + one thread per live connection** speak the
//!   wire protocol ([`crate::api::wire::serve_connection`]).
//!
//! The previous design spawned a fresh OS thread per dispatch, so
//! thread count — and scheduler pressure — grew with load;
//! [`RtServer::exec_threads`] exposes the (constant) executor-side
//! count so tests can pin the invariant under a burst.
//!
//! # Lock discipline on the submit path
//!
//! A submit on an M-shard cluster locks at most one [`ControlPlane`]
//! — the routed shard's:
//!
//! * Shard load snapshots ([`crate::cluster::ShardLoad`]) read per-shard
//!   atomics published under the plane lock at every mutation, so
//!   admission control and routing never lock any plane.
//! * The router sits behind a read-mostly `RwLock` and
//!   [`crate::cluster::Router::route`] takes `&self` (StickyCh's ring
//!   is immutable after build; RoundRobin keeps an atomic cursor), so
//!   concurrent submits route in parallel.
//! * The ticket registry is sharded by ticket id ([`TICKET_SHARDS`]
//!   slots), and invocation→ticket maps are per plane-shard, so
//!   concurrent clients don't serialize on one mutex.
//! * `stats` is O(shards) over atomics — the aggregate counters
//!   (completions, latency sum, cold starts) are maintained at
//!   completion time, and no plane is ever locked to answer it.
//!
//! # Ownership: handles vs the shutdown guard
//!
//! All serving state lives in one shared `Inner`. [`RtHandle`] is a
//! cloneable `Arc` view of it — connections, the accept loop, and
//! embedders hold handles, and dropping a handle is inert. The
//! constructor-returned guard ([`RtServer`]/[`RtCluster`]) is the
//! *single* owner of shutdown: only its `shutdown()`/`Drop` stops the
//! background threads (timer, workers, monitors) and the accept loop.
//! Stopping the guard abandons modeled in-flight work still parked on
//! the timer (their waiters see a deadline/unknown-ticket, exactly as
//! under process teardown); in-flight PJRT executions finish their
//! current job. (The historical drop bug — per-connection guard clones
//! running `Drop::drop → shutdown()` on first disconnect — is still
//! pinned by a regression test in `rust/tests/wire_protocol.rs`.)

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::types::{
    ApiError, DescribeInfo, InvokeOutcome, StatsSnapshot, Ticket, PROTOCOL_VERSION,
};
use crate::api::Frontend;
use crate::clock::{Clock, RealClock};
use crate::cluster::{ClusterConfig, Router, RouterKind, ShardLoad};
use crate::plane::{ControlPlane, Dispatch, PlaneConfig};
use crate::runtime::PjrtRuntime;
use crate::types::{to_secs, FuncId, InvocationId, Nanos, StartKind};
use crate::workload::Workload;

/// Worker threads per shard unless overridden (`with_workers`). Total
/// executor-side threads = `shards × workers + 1` (the timer).
pub const DEFAULT_WORKERS: usize = 4;

/// Ticket-registry shards: tickets hash to a slot by id, so concurrent
/// clients touching different tickets never contend on one mutex.
pub const TICKET_SHARDS: usize = 16;

/// Job sent to the PJRT executor thread.
struct ExecJob {
    artifact: &'static str,
    reply: Sender<Duration>,
}

/// Completion bookkeeping for one accepted invocation.
enum TicketEntry {
    /// Still running; waiters are woken (all of them) on completion.
    Pending { waiters: Vec<Sender<InvokeOutcome>> },
    /// Completed but not yet claimed by `wait`/`poll`.
    Done(InvokeOutcome),
}

/// Ticket registry slot with a bound on completed-but-unclaimed
/// entries, so fire-and-forget async clients (or crashed ones) cannot
/// grow the table without limit on a long-running server: beyond the
/// slot's `max_done` unclaimed completions, the oldest are evicted (a
/// later `wait` on one gets `unknown-ticket`, exactly as if it had
/// been claimed). The server keeps [`TICKET_SHARDS`] slots whose
/// bounds sum to [`TicketTable::DEFAULT_MAX_DONE`].
struct TicketTable {
    entries: HashMap<u64, TicketEntry>,
    /// Completion order of `Done` entries; may contain stale ids of
    /// since-claimed tickets (filtered during eviction — ids are never
    /// reused, so staleness is unambiguous).
    done_order: VecDeque<u64>,
    /// Live `Done` entries (kept ≤ `max_done`).
    done_count: usize,
    max_done: usize,
}

impl TicketTable {
    /// Unclaimed completions retained across all slots before the
    /// oldest are dropped.
    const DEFAULT_MAX_DONE: usize = 1 << 16;

    fn with_max(max_done: usize) -> Self {
        Self {
            entries: HashMap::new(),
            done_order: VecDeque::new(),
            done_count: 0,
            max_done,
        }
    }

    fn insert_pending(&mut self, id: u64) {
        self.entries.insert(
            id,
            TicketEntry::Pending {
                waiters: Vec::new(),
            },
        );
    }

    /// Remove an entry, keeping the unclaimed-done count in sync.
    fn remove(&mut self, id: u64) -> Option<TicketEntry> {
        let entry = self.entries.remove(&id);
        if matches!(entry, Some(TicketEntry::Done(_))) {
            self.done_count -= 1;
        }
        entry
    }

    /// Mark `id` done, returning the displaced entry (the waiters to
    /// wake). Evicts the oldest unclaimed completions over the bound.
    fn complete(&mut self, id: u64, outcome: InvokeOutcome) -> Option<TicketEntry> {
        let prev = self.entries.insert(id, TicketEntry::Done(outcome));
        if !matches!(prev, Some(TicketEntry::Done(_))) {
            self.done_count += 1;
        }
        self.done_order.push_back(id);
        while self.done_count > self.max_done {
            let Some(old) = self.done_order.pop_front() else {
                break;
            };
            if matches!(self.entries.get(&old), Some(TicketEntry::Done(_))) {
                self.entries.remove(&old);
                self.done_count -= 1;
            }
        }
        // The order queue accumulates stale ids of promptly-claimed
        // tickets; compact it once it doubles past the live bound
        // (amortized O(1) per completion, keeps both structures bounded).
        if self.done_order.len() > self.max_done.saturating_mul(2).max(64) {
            let entries = &self.entries;
            self.done_order
                .retain(|id| matches!(entries.get(id), Some(TicketEntry::Done(_))));
        }
        prev
    }
}

/// Work handed to a shard's worker pool by the timer thread.
enum WorkItem {
    /// The dispatch's scaled pre-exec delay (boot + blocking) elapsed:
    /// touch the plane at the wall-clock exec start, then execute
    /// (PJRT inline, or schedule the modeled completion on the timer).
    ExecStart(Dispatch),
    /// The modeled service time elapsed (model mode only): complete
    /// the invocation and fulfill its ticket.
    Complete { d: Dispatch, exec_t0: Nanos },
}

/// One timer-wheel entry; ordered by `(due, seq)` so same-instant
/// events fire in schedule order.
struct TimerEntry {
    due: Instant,
    seq: u64,
    shard: usize,
    item: WorkItem,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.due, self.seq).cmp(&(other.due, other.seq))
    }
}

/// Binary-heap timer wheel: one thread sleeps until the earliest
/// deadline and hands due events to shard worker queues. Scheduling is
/// lock + push + notify; O(log n) in outstanding events.
struct Timer {
    heap: Mutex<BinaryHeap<Reverse<TimerEntry>>>,
    cv: Condvar,
    seq: AtomicU64,
}

impl Timer {
    fn new() -> Self {
        Self {
            heap: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            seq: AtomicU64::new(0),
        }
    }

    fn schedule(&self, due: Instant, shard: usize, item: WorkItem) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.heap
            .lock()
            .unwrap()
            .push(Reverse(TimerEntry {
                due,
                seq,
                shard,
                item,
            }));
        self.cv.notify_one();
    }
}

/// Per-shard serving state: the plane, its published load snapshot,
/// the worker inbox, and the monitor's park gate.
struct ShardState {
    plane: Mutex<ControlPlane>,
    /// Load snapshot published under the plane lock at every mutation;
    /// admission control, routing, and `stats` read these without ever
    /// locking the plane.
    pending: AtomicUsize,
    in_flight: AtomicUsize,
    /// Fleet capacity (V100-equivalents) for [`ShardLoad`].
    capacity: f64,
    /// Worker-pool inbox, fed by the timer thread.
    work: Mutex<VecDeque<WorkItem>>,
    work_cv: Condvar,
    /// Monitor park gate: true ⇒ a submit woke an idle shard.
    gate: Mutex<bool>,
    gate_cv: Condvar,
    /// Monitor ticks that actually locked the plane (diagnostics; an
    /// idle shard's count must not grow).
    ticks: AtomicU64,
    /// shard-local invocation id → ticket, registered under the plane
    /// lock at submit time so a racing completion can never observe an
    /// unmapped invocation.
    inv_tickets: Mutex<HashMap<InvocationId, Ticket>>,
}

impl ShardState {
    fn new(plane: ControlPlane, capacity: f64) -> Self {
        Self {
            plane: Mutex::new(plane),
            pending: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            capacity,
            work: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            gate: Mutex::new(false),
            gate_cv: Condvar::new(),
            ticks: AtomicU64::new(0),
            inv_tickets: Mutex::new(HashMap::new()),
        }
    }

    fn depth(&self) -> usize {
        self.pending.load(Ordering::SeqCst) + self.in_flight.load(Ordering::SeqCst)
    }

    fn load(&self) -> ShardLoad {
        ShardLoad {
            pending: self.pending.load(Ordering::SeqCst),
            in_flight: self.in_flight.load(Ordering::SeqCst),
            capacity: self.capacity,
        }
    }

    /// Publish the plane's load counters (call under the plane lock).
    fn publish(&self, plane: &ControlPlane) {
        self.pending.store(plane.pending(), Ordering::SeqCst);
        self.in_flight.store(plane.in_flight(), Ordering::SeqCst);
    }

    fn push_work(&self, item: WorkItem) {
        self.work.lock().unwrap().push_back(item);
        self.work_cv.notify_one();
    }

    /// Wake a (possibly) parked monitor: a submit landed on this shard.
    fn wake_monitor(&self) {
        let mut g = self.gate.lock().unwrap();
        *g = true;
        self.gate_cv.notify_one();
    }
}

/// Shared serving state: shards, router, tickets, executor, timer.
struct Inner {
    /// Frontend kind for `describe`: `rt-server` or `rt-cluster`.
    kind: &'static str,
    router_name: &'static str,
    shards: Vec<ShardState>,
    /// Routing decision for each arrival. Read-mostly: every submit
    /// takes the read lock (routers mutate through atomics), so
    /// concurrent submits route in parallel.
    router: RwLock<Box<dyn Router>>,
    clock: RealClock,
    /// Modeled-delay scale: 1 virtual second sleeps `scale` real seconds.
    scale: f64,
    exec_tx: Option<Sender<ExecJob>>,
    /// Ticket registry, sharded by `ticket % TICKET_SHARDS`.
    tickets: Vec<Mutex<TicketTable>>,
    /// Lock-free admission lookup: registered name *and* class name →
    /// id, precomputed from the workload (identical on every shard) so
    /// submits never scan — or allocate — under a plane lock.
    func_index: HashMap<String, FuncId>,
    /// FuncId → registered name (reply field), precomputed so the
    /// completion path never locks a plane for a name.
    func_names: Vec<String>,
    /// FuncId → catalog class name (PJRT artifact key).
    class_names: Vec<&'static str>,
    /// Precomputed `describe` fields (identical on every shard).
    policy: String,
    functions: Vec<String>,
    timer: Timer,
    next_ticket: AtomicU64,
    /// Admission bound on total queued work (`usize::MAX` = unlimited).
    max_pending: AtomicUsize,
    running: AtomicBool,
    // O(1) stats aggregates, maintained at completion time.
    completed: AtomicUsize,
    lat_sum_ns: AtomicU64,
    cold_starts: AtomicUsize,
    /// Executor-side threads spawned (timer + workers): a function of
    /// configuration, asserted by tests to be load-independent.
    exec_threads: AtomicUsize,
}

impl Inner {
    fn ticket_slot(&self, id: u64) -> &Mutex<TicketTable> {
        &self.tickets[(id % TICKET_SHARDS as u64) as usize]
    }

    /// Wake every parked/sleeping background thread for shutdown. Each
    /// notify holds the matching mutex so a thread between its
    /// `running` check and its wait cannot miss the wakeup.
    fn wake_all(&self) {
        {
            let _g = self.timer.heap.lock().unwrap();
            self.timer.cv.notify_all();
        }
        for s in &self.shards {
            {
                let _g = s.work.lock().unwrap();
                s.work_cv.notify_all();
            }
            {
                let _g = s.gate.lock().unwrap();
                s.gate_cv.notify_all();
            }
        }
    }
}

/// Cloneable, shutdown-free view of a running frontend. Connections and
/// embedders hold these; only the constructor-returned guard can stop
/// the server.
#[derive(Clone)]
pub struct RtHandle {
    inner: Arc<Inner>,
}

// ---------------------------------------------------------------------
// Frontend implementation over Inner.
// ---------------------------------------------------------------------

fn describe_inner(inner: &Arc<Inner>) -> DescribeInfo {
    DescribeInfo {
        proto: PROTOCOL_VERSION,
        server: inner.kind.to_string(),
        policy: inner.policy.clone(),
        shards: inner.shards.len(),
        router: inner.router_name.to_string(),
        functions: inner.functions.clone(),
    }
}

fn submit_inner(inner: &Arc<Inner>, name: &str) -> Result<Ticket, ApiError> {
    if !inner.running.load(Ordering::SeqCst) {
        return Err(ApiError::ShuttingDown);
    }
    let Some(&func) = inner.func_index.get(name) else {
        return Err(ApiError::UnknownFunction {
            name: name.to_string(),
        });
    };
    // Admission control + routing over the published atomics: no plane
    // lock until the routed shard is known, and no steady-state
    // allocation — the load snapshot lives in a per-thread buffer.
    thread_local! {
        static LOADS_BUF: std::cell::RefCell<Vec<ShardLoad>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }
    let shard = LOADS_BUF.with(|buf| -> Result<usize, ApiError> {
        let mut loads = buf.borrow_mut();
        loads.clear();
        loads.extend(inner.shards.iter().map(|s| s.load()));
        let pending: usize = loads.iter().map(|l| l.pending).sum();
        let limit = inner.max_pending.load(Ordering::SeqCst);
        if pending >= limit {
            return Err(ApiError::Overloaded { pending, limit });
        }
        Ok(inner.router.read().unwrap().route(func, &loads))
    })?;
    debug_assert!(shard < inner.shards.len(), "router out of range");
    let ticket = Ticket(inner.next_ticket.fetch_add(1, Ordering::SeqCst));
    inner
        .ticket_slot(ticket.0)
        .lock()
        .unwrap()
        .insert_pending(ticket.0);
    let st = &inner.shards[shard];
    let (was_idle, ds) = {
        // The only plane lock on the submit path: the routed shard's.
        let mut plane = st.plane.lock().unwrap();
        // Exact idle check under the lock (a pre-lock snapshot could
        // race a completion and leave the monitor parked with work).
        let was_idle = plane.pending() + plane.in_flight() == 0;
        let now = inner.clock.now();
        let (inv, ds) = plane.on_arrival(func, now);
        // Map under the plane lock (see ShardState::inv_tickets).
        st.inv_tickets.lock().unwrap().insert(inv, ticket);
        st.publish(&plane);
        (was_idle, ds)
    };
    if was_idle {
        st.wake_monitor();
    }
    schedule_dispatches(inner, shard, ds);
    Ok(ticket)
}

fn wait_inner(
    inner: &Arc<Inner>,
    ticket: Ticket,
    deadline: Option<Duration>,
) -> Result<InvokeOutcome, ApiError> {
    let rx = {
        let mut tickets = inner.ticket_slot(ticket.0).lock().unwrap();
        match tickets.remove(ticket.0) {
            None => return Err(ApiError::UnknownTicket { ticket }),
            // Already completed: claiming removes the entry.
            Some(TicketEntry::Done(o)) => return Ok(o),
            Some(TicketEntry::Pending { mut waiters }) => {
                let (tx, rx) = channel();
                waiters.push(tx);
                tickets
                    .entries
                    .insert(ticket.0, TicketEntry::Pending { waiters });
                rx
            }
        }
    };
    let outcome = match deadline {
        // Expired: report the ticket so the (possibly sync-invoking)
        // client can still redeem the run-to-completion invocation.
        Some(dl) => rx.recv_timeout(dl).map_err(|_| ApiError::DeadlineExceeded {
            waited_ms: dl.as_millis() as u64,
            ticket: Some(ticket),
        })?,
        // Sender-side drop (process teardown) surfaces as shutdown.
        None => rx.recv().map_err(|_| ApiError::ShuttingDown)?,
    };
    // Claimed: reclaim the entry (concurrent waiters were all woken by
    // the same fulfillment; whichever removes second is a no-op).
    inner.ticket_slot(ticket.0).lock().unwrap().remove(ticket.0);
    Ok(outcome)
}

fn poll_inner(inner: &Arc<Inner>, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
    let mut tickets = inner.ticket_slot(ticket.0).lock().unwrap();
    match tickets.remove(ticket.0) {
        None => Err(ApiError::UnknownTicket { ticket }),
        // Done: claiming removes the entry, like a successful wait.
        Some(TicketEntry::Done(o)) => Ok(Some(o)),
        Some(pending @ TicketEntry::Pending { .. }) => {
            tickets.entries.insert(ticket.0, pending);
            Ok(None)
        }
    }
}

/// O(shards) over atomics — never locks a plane. The aggregates
/// (completions, latency sum, cold starts) are bumped on the completion
/// path *after* the plane publishes its load, so a waiter that has just
/// been fulfilled observes its own invocation in the totals.
fn stats_inner(inner: &Arc<Inner>) -> StatsSnapshot {
    let n = inner.completed.load(Ordering::SeqCst);
    let mut s = StatsSnapshot {
        invocations: n,
        ..Default::default()
    };
    for st in &inner.shards {
        s.pending += st.pending.load(Ordering::SeqCst);
        s.in_flight += st.in_flight.load(Ordering::SeqCst);
    }
    if n > 0 {
        s.mean_latency_ms = inner.lat_sum_ns.load(Ordering::SeqCst) as f64 / n as f64 / 1e6;
        s.cold_ratio = inner.cold_starts.load(Ordering::SeqCst) as f64 / n as f64;
    }
    s
}

/// Single copy of the [`Frontend`] wiring, stamped onto every type that
/// exposes the shared `Inner` (the handle and both guards — identical
/// behavior by construction). `shutdown` only flips admission; joining
/// the background threads needs a guard's own `stop()` or `Drop`.
macro_rules! impl_frontend_via_inner {
    ($ty:ty) => {
        impl Frontend for $ty {
            fn describe(&self) -> DescribeInfo {
                describe_inner(&self.inner)
            }
            fn submit(&self, func: &str) -> Result<Ticket, ApiError> {
                submit_inner(&self.inner, func)
            }
            fn wait(
                &self,
                ticket: Ticket,
                deadline: Option<Duration>,
            ) -> Result<InvokeOutcome, ApiError> {
                wait_inner(&self.inner, ticket, deadline)
            }
            fn poll(&self, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
                poll_inner(&self.inner, ticket)
            }
            fn stats(&self) -> StatsSnapshot {
                stats_inner(&self.inner)
            }
            fn shutdown(&self) {
                self.inner.running.store(false, Ordering::SeqCst);
            }
        }
    };
}

impl_frontend_via_inner!(RtHandle);
impl_frontend_via_inner!(RtServer);
impl_frontend_via_inner!(RtCluster);

/// Single copy of the shutdown-guard surface, stamped onto both guards
/// (`RtServer`, `RtCluster`): handle/serve/backpressure/diagnostics
/// plus the stop-and-join that only a guard — never a dropped
/// connection handle — may trigger.
macro_rules! impl_guard {
    ($ty:ty) => {
        impl $ty {
            /// Cloneable, shutdown-free view for connections and embedding.
            pub fn handle(&self) -> RtHandle {
                RtHandle {
                    inner: Arc::clone(&self.inner),
                }
            }

            /// Serve the protocol on `addr` (port 0 picks a free one).
            pub fn serve(&self, addr: &str) -> anyhow::Result<std::net::SocketAddr> {
                serve_on(self.handle(), addr)
            }

            /// Backpressure bound: reject (`overloaded`) when total
            /// queued work is at/above `limit` at submit time.
            pub fn set_max_pending(&self, limit: usize) {
                self.inner.max_pending.store(limit, Ordering::SeqCst);
            }

            /// Executor-side threads spawned (timer + worker pools) —
            /// a function of configuration, never of offered load.
            pub fn exec_threads(&self) -> usize {
                self.inner.exec_threads.load(Ordering::SeqCst)
            }

            /// Monitor ticks that locked a plane, summed over shards.
            /// Stays flat while the server is idle (monitors park).
            pub fn monitor_ticks(&self) -> u64 {
                self.inner
                    .shards
                    .iter()
                    .map(|s| s.ticks.load(Ordering::SeqCst))
                    .sum()
            }

            /// Stop admissions and join the background threads (timer,
            /// workers, monitors). Idempotent; also runs on `Drop`.
            /// Only this guard stops the server — dropped connection
            /// handles never do.
            pub fn stop(&self) {
                self.inner.running.store(false, Ordering::SeqCst);
                self.inner.wake_all();
                for h in self.threads.lock().unwrap().drain(..) {
                    let _ = h.join();
                }
            }
        }

        impl Drop for $ty {
            fn drop(&mut self) {
                self.stop();
            }
        }
    };
}

// ---------------------------------------------------------------------
// Construction + background threads.
// ---------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn build_inner(
    kind: &'static str,
    router_name: &'static str,
    workload: Workload,
    plane_cfgs: Vec<PlaneConfig>,
    router: Box<dyn Router>,
    capacities: Vec<f64>,
    artifacts_dir: Option<&std::path::Path>,
    scale: f64,
) -> anyhow::Result<Arc<Inner>> {
    assert!(scale > 0.0);
    let exec_tx = match artifacts_dir {
        Some(dir) => Some(spawn_executor(dir, &workload)?),
        None => None,
    };
    // Admission index, first match wins like the old linear scan:
    // registered name (unique) and class name (first copy). Names are
    // precomputed per FuncId so neither submit nor completion ever
    // allocates or locks a plane for one.
    let mut func_index = HashMap::new();
    let mut func_names = vec![String::new(); workload.len()];
    let mut class_names = vec![""; workload.len()];
    let mut functions = Vec::with_capacity(workload.len());
    for f in &workload.funcs {
        func_index.entry(f.name.clone()).or_insert(f.id);
        func_index.entry(f.class.name.to_string()).or_insert(f.id);
        func_names[f.id.0 as usize] = f.name.clone();
        class_names[f.id.0 as usize] = f.class.name;
        functions.push(f.name.clone());
    }
    let planes: Vec<ControlPlane> = plane_cfgs
        .into_iter()
        .map(|cfg| ControlPlane::new(workload.clone(), cfg))
        .collect();
    let policy = planes[0].policy_name().to_string();
    let shards = planes
        .into_iter()
        .zip(capacities)
        .map(|(plane, cap)| ShardState::new(plane, cap))
        .collect();
    Ok(Arc::new(Inner {
        kind,
        router_name,
        shards,
        router: RwLock::new(router),
        clock: RealClock::new(),
        scale,
        exec_tx,
        tickets: (0..TICKET_SHARDS)
            .map(|_| Mutex::new(TicketTable::with_max(
                TicketTable::DEFAULT_MAX_DONE / TICKET_SHARDS,
            )))
            .collect(),
        func_index,
        func_names,
        class_names,
        policy,
        functions,
        timer: Timer::new(),
        next_ticket: AtomicU64::new(0),
        max_pending: AtomicUsize::new(usize::MAX),
        running: AtomicBool::new(true),
        completed: AtomicUsize::new(0),
        lat_sum_ns: AtomicU64::new(0),
        cold_starts: AtomicUsize::new(0),
        exec_threads: AtomicUsize::new(0),
    }))
}

/// Spawn the fixed background set: the timer thread, `workers` pool
/// threads per shard, and one monitor per shard. This is the *only*
/// place serving threads are created — nothing on the per-request or
/// per-dispatch path spawns.
fn spawn_threads(inner: &Arc<Inner>, workers: usize) -> Vec<thread::JoinHandle<()>> {
    assert!(workers >= 1, "worker pool needs at least one thread");
    let mut hs = Vec::with_capacity(1 + inner.shards.len() * (workers + 1));
    inner.exec_threads.fetch_add(1, Ordering::SeqCst);
    {
        let t = Arc::clone(inner);
        hs.push(thread::spawn(move || timer_loop(t)));
    }
    for shard in 0..inner.shards.len() {
        for _ in 0..workers {
            inner.exec_threads.fetch_add(1, Ordering::SeqCst);
            let t = Arc::clone(inner);
            hs.push(thread::spawn(move || worker_loop(t, shard)));
        }
        let t = Arc::clone(inner);
        hs.push(thread::spawn(move || monitor_loop(t, shard)));
    }
    hs
}

/// Timer thread: sleep until the earliest deadline, then hand the due
/// event to its shard's worker pool. Never locks a plane.
fn timer_loop(inner: Arc<Inner>) {
    let mut heap = inner.timer.heap.lock().unwrap();
    loop {
        if !inner.running.load(Ordering::SeqCst) {
            return;
        }
        let now = Instant::now();
        let next_due = heap.peek().map(|r| r.0.due);
        match next_due {
            None => {
                heap = inner.timer.cv.wait(heap).unwrap();
            }
            Some(due) if due <= now => {
                let Reverse(e) = heap.pop().unwrap();
                drop(heap);
                inner.shards[e.shard].push_work(e.item);
                heap = inner.timer.heap.lock().unwrap();
            }
            Some(due) => {
                let (h, _) = inner
                    .timer
                    .cv
                    .wait_timeout(heap, due - now)
                    .unwrap();
                heap = h;
            }
        }
    }
}

/// Worker thread: drain the shard's inbox. Model-mode items are pure
/// bookkeeping (no sleeping); PJRT items block on the executor, which
/// bounds concurrent jobs at the pool size.
fn worker_loop(inner: Arc<Inner>, shard: usize) {
    loop {
        let item = {
            let mut q = inner.shards[shard].work.lock().unwrap();
            loop {
                if let Some(item) = q.pop_front() {
                    break Some(item);
                }
                if !inner.running.load(Ordering::SeqCst) {
                    break None;
                }
                q = inner.shards[shard].work_cv.wait(q).unwrap();
            }
        };
        match item {
            None => return,
            Some(WorkItem::ExecStart(d)) => run_exec_start(&inner, shard, d),
            Some(WorkItem::Complete { d, exec_t0 }) => {
                run_complete(&inner, shard, d, exec_t0)
            }
        }
    }
}

/// Monitor thread for one shard: scaled-free 200 ms-class ticks (the
/// shard's own `monitor_period`, real time), exactly like the paper's
/// NVML poller — utilization sampling, dynamic D, TTL expiry. Parks on
/// the shard's gate while idle: an idle server's planes see no
/// tick-driven lock traffic at all (TTL expiry resumes with the next
/// submit, whose tick fires at current wall time).
fn monitor_loop(inner: Arc<Inner>, shard: usize) {
    let st = &inner.shards[shard];
    let period = Duration::from_nanos(st.plane.lock().unwrap().cfg.monitor_period);
    // Failsafe recheck while parked: the submit-side wake is exact
    // (idleness is decided under the plane lock), so this is pure
    // defense in depth — a recheck wakes the thread but never ticks an
    // idle plane.
    let failsafe = period.saturating_mul(64).max(Duration::from_millis(100));
    while inner.running.load(Ordering::SeqCst) {
        if st.depth() == 0 {
            let mut g = st.gate.lock().unwrap();
            while !*g && inner.running.load(Ordering::SeqCst) && st.depth() == 0 {
                let (gg, _) = st.gate_cv.wait_timeout(g, failsafe).unwrap();
                g = gg;
            }
            *g = false;
            continue;
        }
        thread::sleep(period);
        if !inner.running.load(Ordering::SeqCst) {
            return;
        }
        let now = inner.clock.now();
        let ds = {
            let mut plane = st.plane.lock().unwrap();
            let ds = plane.on_monitor_tick(now);
            st.publish(&plane);
            ds
        };
        st.ticks.fetch_add(1, Ordering::SeqCst);
        schedule_dispatches(&inner, shard, ds);
    }
}

/// PJRT executor thread: owns the (non-Send) runtime; executes one
/// artifact at a time. The serialization is harmless — the CPU PJRT
/// client is itself internally parallel and stands in for one GPU.
fn spawn_executor(
    dir: &std::path::Path,
    workload: &Workload,
) -> anyhow::Result<Sender<ExecJob>> {
    let (tx, rx): (Sender<ExecJob>, Receiver<ExecJob>) = channel();
    let dir = dir.to_path_buf();
    let names: Vec<&'static str> = {
        let mut v: Vec<&'static str> =
            workload.funcs.iter().map(|f| f.class.name).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
    thread::spawn(move || {
        let mut rt = match PjrtRuntime::new(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        for name in &names {
            if let Err(e) = rt.load_function(name) {
                let _ = ready_tx.send(Err(e));
                return;
            }
        }
        let _ = ready_tx.send(Ok(()));
        while let Ok(job) = rx.recv() {
            let t0 = std::time::Instant::now();
            let _ = rt.execute(job.artifact);
            let _ = job.reply.send(t0.elapsed());
        }
    });
    ready_rx.recv().expect("executor thread died")?;
    Ok(tx)
}

/// Scaled model-time → wall-clock duration.
fn scaled(scale: f64, ns: Nanos) -> Duration {
    Duration::from_secs_f64(to_secs(ns) * scale)
}

/// Park each dispatch on the timer until its (scaled) exec start. The
/// per-dispatch cost is one heap push — no thread is spawned anywhere
/// on this path.
fn schedule_dispatches(inner: &Arc<Inner>, shard: usize, ds: Vec<Dispatch>) {
    if ds.is_empty() {
        return;
    }
    let now = Instant::now();
    for d in ds {
        let delay = scaled(inner.scale, d.exec_start.saturating_sub(d.at));
        inner
            .timer
            .schedule(now + delay, shard, WorkItem::ExecStart(d));
    }
}

/// The dispatch reached its exec start: touch the plane (the sim
/// engine's Touch event, live), then execute — PJRT inline on this
/// worker, or the modeled service as a timer event.
fn run_exec_start(inner: &Arc<Inner>, shard: usize, d: Dispatch) {
    let exec_t0 = inner.clock.now();
    // Exact utilization-integral touch at the wall-clock exec start.
    inner.shards[shard].plane.lock().unwrap().touch(exec_t0);
    if let Some(tx) = &inner.exec_tx {
        let (rtx, rrx) = channel();
        if tx
            .send(ExecJob {
                artifact: inner.class_names[d.func.0 as usize],
                reply: rtx,
            })
            .is_ok()
        {
            let _ = rrx.recv();
        }
        run_complete(inner, shard, d, exec_t0);
    } else {
        // Model mode: the worker never sleeps — completion fires from
        // the timer after the scaled modeled service time.
        inner.timer.schedule(
            Instant::now() + scaled(inner.scale, d.exec),
            shard,
            WorkItem::Complete { d, exec_t0 },
        );
    }
}

/// Completion: retire the invocation on its plane, bump the stats
/// aggregates, fulfill the submitter's ticket, and schedule any
/// unlocked dispatches.
fn run_complete(inner: &Arc<Inner>, shard: usize, d: Dispatch, exec_t0: Nanos) {
    let st = &inner.shards[shard];
    let now = inner.clock.now();
    let (rec, ds) = {
        let mut plane = st.plane.lock().unwrap();
        let r = plane.on_complete(d.inv, now);
        st.publish(&plane);
        r
    };
    // Completion matching: the plane hands back the completed
    // invocation's own record (not `records.last()`, which under
    // concurrent completions may belong to someone else).
    if let Some(rec) = rec {
        debug_assert_eq!(rec.inv, d.inv);
        let lat_ns = rec.completed.saturating_sub(rec.arrived);
        inner.lat_sum_ns.fetch_add(lat_ns, Ordering::SeqCst);
        if rec.start_kind == StartKind::Cold {
            inner.cold_starts.fetch_add(1, Ordering::SeqCst);
        }
        inner.completed.fetch_add(1, Ordering::SeqCst);
        let mapped = st.inv_tickets.lock().unwrap().remove(&d.inv);
        if let Some(ticket) = mapped {
            fulfill(
                inner,
                ticket,
                InvokeOutcome {
                    ticket,
                    func: inner.func_names[d.func.0 as usize].clone(),
                    shard,
                    gpu: rec.gpu.0,
                    start_kind: rec.start_kind,
                    latency_ms: to_secs(lat_ns) * 1e3,
                    exec_ms: to_secs(now.saturating_sub(exec_t0)) * 1e3,
                },
            );
        }
    }
    schedule_dispatches(inner, shard, ds);
}

/// Mark a ticket done and wake every waiter blocked on it.
fn fulfill(inner: &Arc<Inner>, ticket: Ticket, outcome: InvokeOutcome) {
    let prev = inner
        .ticket_slot(ticket.0)
        .lock()
        .unwrap()
        .complete(ticket.0, outcome.clone());
    if let Some(TicketEntry::Pending { waiters }) = prev {
        for w in waiters {
            let _ = w.send(outcome.clone());
        }
    }
}

/// Accept loop on `addr`; every connection is served over a cloned
/// [`RtHandle`] (never the shutdown guard — see the module docs).
fn serve_on(handle: RtHandle, addr: &str) -> anyhow::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    thread::spawn(move || {
        for stream in listener.incoming() {
            if !handle.inner.running.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let conn = handle.clone();
            thread::spawn(move || crate::api::wire::serve_connection(&conn, stream));
        }
    });
    Ok(local)
}

// ---------------------------------------------------------------------
// RtServer: the single-plane frontend.
// ---------------------------------------------------------------------

/// Single-plane wall-clock frontend; the shutdown-owning guard.
/// Construct with [`RtServer::new`], serve TCP with [`RtServer::serve`],
/// embed via [`RtServer::handle`] or the [`Frontend`] impl.
pub struct RtServer {
    inner: Arc<Inner>,
    threads: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl RtServer {
    /// `artifacts_dir`: load + compile HLO artifacts and execute them on
    /// dispatch (real execution). `None`: sleep the modeled service time
    /// instead (pure control-plane demo). Worker pool defaults to
    /// [`DEFAULT_WORKERS`]; see [`RtServer::with_workers`].
    pub fn new(
        workload: Workload,
        cfg: PlaneConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
    ) -> anyhow::Result<Self> {
        Self::with_workers(workload, cfg, artifacts_dir, scale, DEFAULT_WORKERS)
    }

    /// [`RtServer::new`] with an explicit per-shard worker-pool size.
    pub fn with_workers(
        workload: Workload,
        cfg: PlaneConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
        workers: usize,
    ) -> anyhow::Result<Self> {
        let capacities = vec![cfg.fleet_capacity()];
        // Trivial ring: every routing question answers shard 0.
        let router = RouterKind::RoundRobin.build(1, 1.0, 0, &capacities);
        let inner = build_inner(
            "rt-server",
            "single",
            workload,
            vec![cfg],
            router,
            capacities,
            artifacts_dir,
            scale,
        )?;
        let threads = Mutex::new(spawn_threads(&inner, workers));
        Ok(Self { inner, threads })
    }
}

impl_guard!(RtServer);

// ---------------------------------------------------------------------
// RtCluster: N shards behind a live router.
// ---------------------------------------------------------------------

/// Sharded wall-clock frontend: N independent control planes behind a
/// [`crate::cluster::Router`], serving real TCP traffic. The shutdown-
/// owning guard, like [`RtServer`].
pub struct RtCluster {
    inner: Arc<Inner>,
    threads: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl RtCluster {
    /// Build `cfg.n_shards` planes (heterogeneous via
    /// [`ClusterConfig::shard_planes`]), the capacity-weighted router,
    /// and the fixed background set (timer, [`DEFAULT_WORKERS`] workers
    /// per shard, one monitor per shard).
    pub fn new(
        workload: Workload,
        cfg: ClusterConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
    ) -> anyhow::Result<Self> {
        Self::with_workers(workload, cfg, artifacts_dir, scale, DEFAULT_WORKERS)
    }

    /// [`RtCluster::new`] with an explicit per-shard worker-pool size.
    pub fn with_workers(
        workload: Workload,
        cfg: ClusterConfig,
        artifacts_dir: Option<&std::path::Path>,
        scale: f64,
        workers: usize,
    ) -> anyhow::Result<Self> {
        assert!(cfg.n_shards >= 1, "cluster needs at least one shard");
        assert!(
            cfg.shard_planes.is_empty() || cfg.shard_planes.len() == cfg.n_shards,
            "shard_planes must be empty or hold one config per shard"
        );
        let capacities = cfg.shard_capacities();
        let router = cfg
            .router
            .build(cfg.n_shards, cfg.load_factor, cfg.seed, &capacities);
        let planes: Vec<PlaneConfig> =
            (0..cfg.n_shards).map(|s| cfg.plane_for(s).clone()).collect();
        let inner = build_inner(
            "rt-cluster",
            cfg.router.name(),
            workload,
            planes,
            router,
            capacities,
            artifacts_dir,
            scale,
        )?;
        let threads = Mutex::new(spawn_threads(&inner, workers));
        Ok(Self { inner, threads })
    }

    pub fn n_shards(&self) -> usize {
        self.inner.shards.len()
    }
}

impl_guard!(RtCluster);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::MS;
    use crate::workload::catalog::by_name;

    fn workload() -> Workload {
        let mut w = Workload::default();
        w.register(by_name("isoneural").unwrap(), 0, 1.0);
        w.register(by_name("fft").unwrap(), 0, 1.0);
        w
    }

    fn fast_cfg() -> PlaneConfig {
        PlaneConfig {
            monitor_period: 20 * MS,
            ..Default::default()
        }
    }

    const WAIT: Option<Duration> = Some(Duration::from_secs(30));

    #[test]
    fn submit_completes_in_model_mode() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let ticket = srv.submit("isoneural-0").unwrap();
        let c = srv.wait(ticket, WAIT).unwrap();
        assert_eq!(c.ticket, ticket);
        assert_eq!(c.func, "isoneural-0");
        assert_eq!(c.shard, 0);
        assert_eq!(c.start_kind, StartKind::Cold);
        assert!(c.latency_ms > 0.0);
        let s = srv.stats();
        assert_eq!(s.invocations, 1);
        assert!(s.mean_latency_ms > 0.0);
        assert!((s.cold_ratio - 1.0).abs() < 1e-9);
        // Claimed tickets are reclaimed.
        assert_eq!(
            srv.wait(ticket, WAIT).unwrap_err().code(),
            "unknown-ticket"
        );
    }

    #[test]
    fn class_name_resolves_like_registered_name() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let t = srv.submit("fft").unwrap();
        assert_eq!(srv.wait(t, WAIT).unwrap().func, "fft-0");
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.0005).unwrap();
        let names = ["isoneural-0", "fft-0"];
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| srv.submit(names[i % 2]).unwrap())
            .collect();
        for t in tickets {
            srv.wait(t, WAIT).unwrap();
        }
        assert_eq!(srv.stats().invocations, 6);
    }

    #[test]
    fn poll_observes_pending_then_done() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.005).unwrap();
        let t = srv.submit("fft-0").unwrap();
        // fft's cold boot is seconds of model time — milliseconds here —
        // so the first poll observes it still running.
        assert_eq!(srv.poll(t).unwrap(), None);
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        let outcome = loop {
            if let Some(o) = srv.poll(t).unwrap() {
                break o;
            }
            assert!(std::time::Instant::now() < deadline, "poll never completed");
            thread::sleep(Duration::from_millis(2));
        };
        assert_eq!(outcome.ticket, t);
        // Consumed by the successful poll.
        assert_eq!(srv.poll(t).unwrap_err().code(), "unknown-ticket");
    }

    #[test]
    fn unknown_function_is_structured() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let err = srv.submit("ghost").unwrap_err();
        assert_eq!(err.code(), "unknown-function");
    }

    #[test]
    fn backpressure_rejects_overload_deterministically() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        srv.set_max_pending(1);
        // Default D=2 on one GPU: two dispatch immediately, the third
        // queues (pending=1), so the fourth submit hits the bound.
        let t1 = srv.submit("fft-0").unwrap();
        let t2 = srv.submit("fft-0").unwrap();
        let t3 = srv.submit("fft-0").unwrap();
        let err = srv.submit("fft-0").unwrap_err();
        assert_eq!(err.code(), "overloaded");
        for t in [t1, t2, t3] {
            srv.wait(t, WAIT).unwrap();
        }
    }

    #[test]
    fn shutdown_rejects_new_work_and_is_guard_owned() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let handle = srv.handle();
        // Dropping handles is inert — admission stays open.
        drop(handle.clone());
        assert!(handle.submit("isoneural-0").is_ok());
        srv.stop();
        assert_eq!(handle.submit("isoneural-0").unwrap_err().code(), "shutting-down");
        assert_eq!(srv.submit("isoneural-0").unwrap_err().code(), "shutting-down");
    }

    #[test]
    fn describe_reports_shape() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let d = srv.describe();
        assert_eq!(d.proto, PROTOCOL_VERSION);
        assert_eq!(d.server, "rt-server");
        assert_eq!(d.shards, 1);
        assert_eq!(d.router, "single");
        assert_eq!(d.policy, "mqfq-sticky");
        assert_eq!(d.functions, vec!["isoneural-0", "fft-0"]);
    }

    #[test]
    fn cluster_frontend_spreads_and_aggregates() {
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.001).unwrap();
        assert_eq!(srv.n_shards(), 2);
        let d = srv.describe();
        assert_eq!(d.server, "rt-cluster");
        assert_eq!(d.shards, 2);
        assert_eq!(d.router, "round-robin");
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| srv.submit("isoneural-0").unwrap())
            .collect();
        let shards: std::collections::HashSet<usize> = tickets
            .into_iter()
            .map(|t| srv.wait(t, WAIT).unwrap().shard)
            .collect();
        assert_eq!(shards.len(), 2, "round-robin must hit both shards");
        assert_eq!(srv.stats().invocations, 4);
    }

    #[test]
    fn cluster_sticky_keeps_a_function_home() {
        let cfg = ClusterConfig {
            n_shards: 4,
            router: RouterKind::StickyCh,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.0005).unwrap();
        let mut shards = std::collections::HashSet::new();
        for _ in 0..6 {
            let t = srv.submit("fft-0").unwrap();
            shards.insert(srv.wait(t, WAIT).unwrap().shard);
        }
        assert_eq!(shards.len(), 1, "light sticky load must stay home");
    }

    #[test]
    fn wait_deadline_trips_then_completion_is_recoverable() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.01).unwrap();
        // fft cold boot ≈ 2.4 s model time → ≈ 24 ms wall; 1 ms deadline
        // trips long before that.
        let t = srv.submit("fft-0").unwrap();
        let err = srv.wait(t, Some(Duration::from_millis(1))).unwrap_err();
        assert_eq!(err.code(), "deadline-exceeded");
        // Run-to-completion: the invocation still finishes and the
        // ticket stays redeemable.
        let o = srv.wait(t, WAIT).unwrap();
        assert_eq!(o.ticket, t);
        assert_eq!(srv.stats().invocations, 1);
    }

    #[test]
    fn unknown_ticket_rejected() {
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        assert_eq!(
            srv.wait(Ticket(999), WAIT).unwrap_err().code(),
            "unknown-ticket"
        );
        assert_eq!(srv.poll(Ticket(999)).unwrap_err().code(), "unknown-ticket");
    }

    #[test]
    fn ticket_table_bounds_unclaimed_completions() {
        let outcome = |n: u64| InvokeOutcome {
            ticket: Ticket(n),
            func: "f".into(),
            shard: 0,
            gpu: 0,
            start_kind: StartKind::Cold,
            latency_ms: 1.0,
            exec_ms: 1.0,
        };
        let mut t = TicketTable::with_max(2);
        for id in 0..5 {
            t.insert_pending(id);
            t.complete(id, outcome(id));
        }
        // Oldest unclaimed completions evicted down to the bound.
        assert_eq!(t.done_count, 2);
        assert!(t.remove(0).is_none());
        assert!(t.remove(1).is_none());
        assert!(t.remove(2).is_none());
        assert!(matches!(t.remove(3), Some(TicketEntry::Done(_))));
        assert!(matches!(t.remove(4), Some(TicketEntry::Done(_))));
        assert_eq!(t.done_count, 0);
        // Promptly-claimed tickets leave stale order ids behind; the
        // compaction keeps both structures bounded.
        for id in 5..500 {
            t.insert_pending(id);
            t.complete(id, outcome(id));
            assert!(matches!(t.remove(id), Some(TicketEntry::Done(_))));
        }
        assert!(t.entries.is_empty());
        assert_eq!(t.done_count, 0);
        assert!(t.done_order.len() <= t.max_done.saturating_mul(2).max(64) + 1);
    }

    #[test]
    fn frontend_trait_objects_serve_both_impls() {
        // The serving layer only sees `&dyn Frontend` — both frontends
        // must be usable through it.
        let server = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        let cluster = RtCluster::new(
            workload(),
            ClusterConfig {
                n_shards: 1,
                plane: fast_cfg(),
                ..Default::default()
            },
            None,
            0.001,
        )
        .unwrap();
        let fronts: [&dyn Frontend; 2] = [&server, &cluster];
        for f in fronts {
            let o = f.invoke("isoneural-0", WAIT).unwrap();
            assert_eq!(o.func, "isoneural-0");
            assert_eq!(f.stats().invocations, 1);
        }
    }

    #[test]
    fn executor_thread_count_is_config_not_load() {
        // shards × workers + 1 timer, fixed at construction...
        let srv = RtServer::with_workers(workload(), fast_cfg(), None, 0.0005, 3).unwrap();
        assert_eq!(srv.exec_threads(), 3 + 1);
        // ...and unchanged by a burst (the 1k-invoke version lives in
        // rust/tests/wire_protocol.rs; this pins the unit invariant).
        let tickets: Vec<Ticket> = (0..64)
            .map(|_| srv.submit("isoneural-0").unwrap())
            .collect();
        for t in tickets {
            srv.wait(t, WAIT).unwrap();
        }
        assert_eq!(srv.exec_threads(), 3 + 1);
        assert_eq!(srv.stats().invocations, 64);
    }

    #[test]
    fn idle_monitor_parks_without_tick_lock_traffic() {
        // 20 ms monitor period: an idle server must not tick at all.
        let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
        thread::sleep(Duration::from_millis(200));
        assert_eq!(srv.monitor_ticks(), 0, "idle monitor must stay parked");
        // Work wakes the monitor; ticks flow while the shard is busy.
        let t = srv.submit("fft-0").unwrap();
        srv.wait(t, WAIT).unwrap();
        // After the shard drains, at most one trailing tick can land;
        // then the count must freeze again.
        thread::sleep(Duration::from_millis(200));
        let settled = srv.monitor_ticks();
        thread::sleep(Duration::from_millis(300));
        assert_eq!(
            srv.monitor_ticks(),
            settled,
            "drained shard's monitor must re-park"
        );
    }

    #[test]
    fn stats_fast_path_matches_plane_recorders() {
        // The O(1) stats aggregates must agree with the ground truth in
        // the per-shard recorders once the server quiesces.
        let cfg = ClusterConfig {
            n_shards: 2,
            router: RouterKind::RoundRobin,
            plane: fast_cfg(),
            ..Default::default()
        };
        let srv = RtCluster::new(workload(), cfg, None, 0.0005).unwrap();
        let tickets: Vec<Ticket> = (0..10)
            .map(|i| srv.submit(["isoneural-0", "fft-0"][i % 2]).unwrap())
            .collect();
        for t in tickets {
            srv.wait(t, WAIT).unwrap();
        }
        let s = srv.stats();
        assert_eq!(s.invocations, 10);
        assert_eq!(s.pending, 0);
        assert_eq!(s.in_flight, 0);
        let (mut n, mut lat_sum, mut cold_sum) = (0usize, 0.0f64, 0.0f64);
        for st in srv.inner.shards.iter() {
            let plane = st.plane.lock().unwrap();
            let k = plane.recorder.len();
            n += k;
            lat_sum += plane.recorder.weighted_avg_latency_s() * k as f64;
            cold_sum += plane.recorder.cold_ratio() * k as f64;
        }
        assert_eq!(n, 10);
        assert!((s.mean_latency_ms - lat_sum / n as f64 * 1e3).abs() < 1e-6);
        assert!((s.cold_ratio - cold_sum / n as f64).abs() < 1e-9);
    }
}
