//! Invocation lifecycle tracing: a fixed-capacity ring of structured
//! [`TraceEvent`]s shared by the sim and the live serving path.
//!
//! Producers ([`crate::plane`], [`crate::cluster`], [`crate::server`])
//! push events with [`TraceRing::push`]; consumers drain them oldest-
//! first over the wire (`trace` verb) or into a JSONL sink
//! (`replay --trace-out`). The ring never blocks the hot path on a
//! slow consumer: when full it overwrites the oldest event and counts
//! the loss in [`TraceRing::dropped_events`].
//!
//! Allocation discipline: a pushed event is a `Copy` struct written
//! into a preallocated slot under a plain (allocation-free) mutex, so
//! steady-state tracing performs zero heap events — the alloc-churn
//! gate (`tests/alloc_churn.rs`) proves it with a counting global
//! allocator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::types::Nanos;

/// Sentinel for "no invocation id" in [`TraceEvent::inv`].
pub const NO_INV: u64 = u64::MAX;
/// Sentinel for "no function id" in [`TraceEvent::func`].
pub const NO_FUNC: u32 = u32::MAX;

/// The lifecycle + scheduler-internal event vocabulary. Sim and wire
/// runs emit the *same* kinds (the plane owns the lifecycle events), so
/// traces from both are directly diffable. See the module docs of
/// [`crate::telemetry`] for the payload table (what `a`/`b`/`c` mean
/// per kind).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// Invocation accepted by a frontend / arrived in the sim.
    Submit,
    /// Router decision: invocation assigned to a shard.
    Route,
    /// Invocation entered its flow queue.
    Enqueue,
    /// Policy picked the invocation and placement chose a device.
    Dispatch,
    /// Sandbox ready; user code starts executing.
    ExecStart,
    /// Invocation finished successfully.
    Complete,
    /// Invocation failed (e.g. stranded by a killed shard).
    Error,
    /// Flow Active/Throttled/Inactive transition.
    FlowState,
    /// Global_VT advanced.
    GlobalVt,
    /// D-token occupancy changed.
    DTokens,
    /// Device memory region evicted.
    Evict,
    /// Shard epoch bumped (membership change).
    Epoch,
    /// Flow emptied but held Active for an anticipatory grace window.
    Grace,
    /// One dispatch decision coalesced several same-flow invocations.
    Batch,
    /// Adaptive-D controller resized the concurrency level.
    DResize,
    /// Estimator predicted-vs-actual execution time at completion.
    Estimate,
    /// An attempt failed (a = FaultKind code, b = attempt index,
    /// c = gpu).
    Fault,
    /// A failed attempt re-queued at the head of its flow
    /// (a = attempts consumed so far).
    Requeue,
    /// Circuit breaker transitioned (a = BreakerState code).
    BreakerState,
    /// Admission shed by the overload policy (a = predicted wait ns).
    Shed,
}

/// Every kind, for vocabulary assertions and exhaustive rendering.
pub const ALL_KINDS: [EventKind; 20] = [
    EventKind::Submit,
    EventKind::Route,
    EventKind::Enqueue,
    EventKind::Dispatch,
    EventKind::ExecStart,
    EventKind::Complete,
    EventKind::Error,
    EventKind::FlowState,
    EventKind::GlobalVt,
    EventKind::DTokens,
    EventKind::Evict,
    EventKind::Epoch,
    EventKind::Grace,
    EventKind::Batch,
    EventKind::DResize,
    EventKind::Estimate,
    EventKind::Fault,
    EventKind::Requeue,
    EventKind::BreakerState,
    EventKind::Shed,
];

impl EventKind {
    /// Stable wire/JSONL name.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Route => "route",
            EventKind::Enqueue => "enqueue",
            EventKind::Dispatch => "dispatch",
            EventKind::ExecStart => "exec_start",
            EventKind::Complete => "complete",
            EventKind::Error => "error",
            EventKind::FlowState => "flow_state",
            EventKind::GlobalVt => "global_vt",
            EventKind::DTokens => "d_tokens",
            EventKind::Evict => "evict",
            EventKind::Epoch => "epoch",
            EventKind::Grace => "grace",
            EventKind::Batch => "batch",
            EventKind::DResize => "d_resize",
            EventKind::Estimate => "estimate",
            EventKind::Fault => "fault",
            EventKind::Requeue => "requeue",
            EventKind::BreakerState => "breaker_state",
            EventKind::Shed => "shed",
        }
    }

    /// Inverse of [`Self::name`] — wire-protocol decode.
    pub fn parse(s: &str) -> Option<Self> {
        ALL_KINDS.iter().copied().find(|k| k.name() == s)
    }
}

/// One structured trace event. `Copy` and fixed-size by design: pushes
/// write into preallocated ring slots without touching the heap. The
/// `a`/`b`/`c` payload words are kind-specific (see the vocabulary
/// table in [`crate::telemetry`]); `inv`/`func` use the `NO_*`
/// sentinels when not applicable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Ring-assigned monotone sequence number (stamped on push).
    pub seq: u64,
    /// Event time: sim virtual nanos or wall nanos since server start.
    pub at: Nanos,
    pub kind: EventKind,
    pub shard: u32,
    pub inv: u64,
    pub func: u32,
    pub a: i64,
    pub b: i64,
    pub c: i64,
}

impl TraceEvent {
    /// A bare event; chain the builder methods for ids and payload.
    pub fn new(at: Nanos, kind: EventKind, shard: u32) -> Self {
        Self {
            seq: 0,
            at,
            kind,
            shard,
            inv: NO_INV,
            func: NO_FUNC,
            a: 0,
            b: 0,
            c: 0,
        }
    }

    pub fn inv(mut self, id: u64) -> Self {
        self.inv = id;
        self
    }

    pub fn func(mut self, f: u32) -> Self {
        self.func = f;
        self
    }

    pub fn a(mut self, v: i64) -> Self {
        self.a = v;
        self
    }

    pub fn b(mut self, v: i64) -> Self {
        self.b = v;
        self
    }

    pub fn c(mut self, v: i64) -> Self {
        self.c = v;
        self
    }

    /// Append the single-line JSONL form (no trailing newline). The
    /// same rendering backs the sim trace sink and the wire `trace`
    /// verb, so sim-vs-wire traces diff line-for-line.
    pub fn render_jsonl_into(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = write!(out, "{{\"seq\":{},\"at\":{},\"kind\":\"{}\"", self.seq, self.at, self.kind.name());
        let _ = write!(out, ",\"shard\":{}", self.shard);
        if self.inv != NO_INV {
            let _ = write!(out, ",\"inv\":{}", self.inv);
        }
        if self.func != NO_FUNC {
            let _ = write!(out, ",\"func\":{}", self.func);
        }
        let _ = write!(out, ",\"a\":{},\"b\":{},\"c\":{}}}", self.a, self.b, self.c);
    }
}

struct RingInner {
    /// Preallocated slots; `head` is the oldest live entry.
    buf: Box<[TraceEvent]>,
    head: usize,
    len: usize,
    next_seq: u64,
}

/// Fixed-capacity drop-oldest ring of trace events.
///
/// Interior mutability behind one plain `Mutex`: the critical section
/// is a couple of word writes (far shorter than the plane lock the
/// producers already hold), and locking a `std` mutex performs no heap
/// allocation, preserving the zero-allocation record path.
pub struct TraceRing {
    inner: Mutex<RingInner>,
    dropped: AtomicU64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let slot = TraceEvent::new(0, EventKind::Submit, 0);
        Self {
            inner: Mutex::new(RingInner {
                buf: vec![slot; capacity].into_boxed_slice(),
                head: 0,
                len: 0,
                next_seq: 0,
            }),
            dropped: AtomicU64::new(0),
        }
    }

    /// Append `ev` (stamping its sequence number), overwriting the
    /// oldest event when full. Returns the stamped sequence number.
    pub fn push(&self, mut ev: TraceEvent) -> u64 {
        let mut r = self.inner.lock().unwrap();
        let seq = r.next_seq;
        r.next_seq += 1;
        ev.seq = seq;
        let cap = r.buf.len();
        if r.len == cap {
            let head = r.head;
            r.buf[head] = ev;
            r.head = (head + 1) % cap;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        } else {
            let idx = (r.head + r.len) % cap;
            r.buf[idx] = ev;
            r.len += 1;
        }
        seq
    }

    /// Remove and return up to `max` events, oldest first. Consecutive
    /// calls page through the stream (each event is delivered once).
    pub fn drain(&self, max: usize) -> Vec<TraceEvent> {
        let mut r = self.inner.lock().unwrap();
        let n = max.min(r.len);
        let cap = r.buf.len();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(r.buf[(r.head + i) % cap]);
        }
        r.head = (r.head + n) % cap;
        r.len -= n;
        out
    }

    /// Events overwritten before any consumer drained them.
    pub fn dropped_events(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: Nanos) -> TraceEvent {
        TraceEvent::new(at, EventKind::Submit, 0).inv(at).func(1)
    }

    #[test]
    fn push_drain_roundtrip_in_order() {
        let r = TraceRing::new(8);
        for i in 0..5 {
            r.push(ev(i));
        }
        assert_eq!(r.len(), 5);
        let got = r.drain(100);
        assert_eq!(got.len(), 5);
        for (i, e) in got.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
            assert_eq!(e.at, i as Nanos);
        }
        assert!(r.is_empty());
        assert_eq!(r.dropped_events(), 0);
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let r = TraceRing::new(4);
        for i in 0..10 {
            r.push(ev(i));
        }
        assert_eq!(r.dropped_events(), 6);
        let got = r.drain(100);
        // The four *newest* events survive, in order.
        assert_eq!(got.iter().map(|e| e.at).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(got.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
    }

    #[test]
    fn drain_pages_through_the_stream() {
        let r = TraceRing::new(8);
        for i in 0..6 {
            r.push(ev(i));
        }
        let first = r.drain(4);
        let second = r.drain(4);
        assert_eq!(first.len(), 4);
        assert_eq!(second.len(), 2);
        assert_eq!(second[0].seq, 4);
        // New pushes land after a partial drain without disturbing order.
        r.push(ev(100));
        let third = r.drain(4);
        assert_eq!(third.len(), 1);
        assert_eq!(third[0].at, 100);
        assert_eq!(third[0].seq, 6);
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in ALL_KINDS {
            assert_eq!(EventKind::parse(k.name()), Some(k));
        }
        assert_eq!(EventKind::parse("teleport"), None);
    }

    #[test]
    fn jsonl_rendering_omits_sentinel_ids() {
        let mut out = String::new();
        let mut e = TraceEvent::new(42, EventKind::GlobalVt, 3).a(1_500_000_000);
        e.seq = 7;
        e.render_jsonl_into(&mut out);
        assert_eq!(
            out,
            "{\"seq\":7,\"at\":42,\"kind\":\"global_vt\",\"shard\":3,\"a\":1500000000,\"b\":0,\"c\":0}"
        );
        out.clear();
        let mut e = TraceEvent::new(1, EventKind::Complete, 0)
            .inv(9)
            .func(2)
            .a(10)
            .b(5)
            .c(1);
        e.seq = 8;
        e.render_jsonl_into(&mut out);
        assert!(out.contains("\"inv\":9") && out.contains("\"func\":2"), "{out}");
    }
}
