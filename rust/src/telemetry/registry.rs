//! Lock-free metrics registry: atomic counters/gauges plus fixed-bucket
//! log₂-scaled latency histograms, registered statically per shard, per
//! device, and per flow-class at construction time.
//!
//! Hot-path discipline: every record is one (or a few) `Relaxed` atomic
//! adds into preallocated storage — no locks, no allocation, no
//! branching on registration state. Export (Prometheus text / JSON) is
//! the slow path and reads the same atomics with `Relaxed` loads; the
//! counters are independently monotone, so an export concurrent with
//! recording sees a consistent-enough snapshot (conservation identities
//! hold once the system quiesces).

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

use crate::util::json::Json;

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (occupancy, VT, ...).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of log₂ buckets: bucket 0 holds the value 0, bucket `i`
/// (1 ≤ i < 63) holds `[2^(i-1), 2^i)`, bucket 63 holds everything
/// from `2^62` up. 64 buckets cover the full `u64` range, so a
/// nanosecond histogram spans sub-ns to ~292 years.
pub const HIST_BUCKETS: usize = 64;

/// Fixed-bucket log₂ histogram. Recording is a single bit-scan plus
/// three relaxed adds; quantiles are answered from the buckets with
/// one-bucket (≤ 2×) resolution — ample for p50/p99/p999 latency
/// tracking, and allocation-free by construction.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket_of(v: u64) -> usize {
        ((u64::BITS - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (the value a quantile query
    /// reports when the target count lands in that bucket).
    fn upper_bound(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= HIST_BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Bucket-resolution quantile (`q` in [0, 1]): the upper bound of
    /// the first bucket whose cumulative count reaches `⌈q·count⌉`.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return Self::upper_bound(i);
            }
        }
        u64::MAX
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("count".into(), Json::Int(self.count() as i64)),
            ("sum".into(), Json::Int(self.sum() as i64)),
            ("mean".into(), Json::Num(self.mean())),
            ("p50".into(), Json::Int(self.quantile(0.50) as i64)),
            ("p99".into(), Json::Int(self.quantile(0.99) as i64)),
            ("p999".into(), Json::Int(self.quantile(0.999) as i64)),
        ])
    }
}

/// Per-shard metric family — one instance per shard, registered at
/// construction so the hot path indexes a fixed slot.
#[derive(Default)]
pub struct ShardMetrics {
    /// Invocations accepted into this shard's plane.
    pub submitted: Counter,
    /// Invocations completed successfully.
    pub completed: Counter,
    /// Invocations failed (kill-stranded, rejected downstream).
    pub errors: Counter,
    /// Start-class counts at dispatch time (§4.3 taxonomy).
    pub cold_starts: Counter,
    pub host_warm_starts: Counter,
    pub gpu_warm_starts: Counter,
    /// Device-memory regions evicted / megabytes moved.
    pub evictions: Counter,
    pub evicted_mb: Counter,
    /// Router decisions that spilled off the sticky home shard.
    pub spills: Counter,
    /// Flow queue-state transitions (the §4.2 Active/Throttled/Inactive
    /// machine — the signals the memory manager consumes).
    pub flow_activations: Counter,
    pub flow_throttles: Counter,
    pub flow_deactivations: Counter,
    /// Instantaneous D-token occupancy (in-flight dispatches).
    pub d_tokens: Gauge,
    /// Last observed Global_VT, in virtual nanoseconds.
    pub global_vt_ns: Gauge,
    /// Anticipatory scheduling: flows held Active past their plain TTL
    /// by an estimator-derived grace window.
    pub grace_holds: Counter,
    /// Dispatch decisions that coalesced >1 same-flow invocation, and
    /// the total invocations that rode in those batches (head + riders).
    pub batch_dispatches: Counter,
    pub batched_invocations: Counter,
    /// Adaptive-D controller level changes.
    pub d_resizes: Counter,
    /// Fault-tolerance layer: injected/observed attempt failures by
    /// kind, retry outcomes, breaker activity, and shed admissions.
    pub faults_device: Counter,
    pub faults_transient: Counter,
    pub faults_straggler: Counter,
    pub retries: Counter,
    pub retry_exhausted: Counter,
    pub breaker_trips: Counter,
    pub breaker_probes: Counter,
    pub shed: Counter,
    /// Estimator accuracy: |predicted − actual| exec time at completion
    /// (only recorded when the estimator had a prediction).
    pub est_abs_error_ns: Histogram,
    /// Last estimator exec-time prediction observed at completion, ns.
    pub est_last_exec_ns: Gauge,
    /// Lifecycle phase latencies, nanoseconds.
    pub queue_wait_ns: Histogram,
    pub exec_ns: Histogram,
    pub e2e_ns: Histogram,
}

/// Per-device metric family.
#[derive(Default)]
pub struct DeviceMetrics {
    pub dispatches: Counter,
    pub cold_starts: Counter,
    pub evictions: Counter,
}

/// Per-flow-class metric family (one per registered function class).
pub struct ClassMetrics {
    pub name: String,
    pub completed: Counter,
    pub exec_ns: Histogram,
}

/// Serving-front-end metric family — one instance per registry (the
/// event loop is per listening address, but the counters aggregate:
/// every loop serving a frontend records into the same family). All
/// recording sites are on the poller thread or the completion path,
/// and every record is a single relaxed atomic op.
#[derive(Default)]
pub struct ServingMetrics {
    /// Currently open wire connections (event loop's slot occupancy).
    pub open_connections: Gauge,
    /// Connections accepted over the server's lifetime.
    pub accepted_connections: Counter,
    /// Requests dispatched per readiness batch — the pipelining
    /// signal: a lockstep client records depth 1, a pipelined burst
    /// records its burst size.
    pub pipeline_depth: Histogram,
    /// Push subscriptions registered (`"push":true` invokes).
    pub push_subscriptions: Counter,
    /// Push completions actually delivered to a live subscriber.
    pub push_notifications: Counter,
    /// Push completions dropped because the subscriber disconnected
    /// (or its deadline already answered) — the ticket stays
    /// redeemable, only the notification is lost.
    pub push_dropped: Counter,
    /// Connections force-closed past the outbound high-water mark
    /// (slow-client protection).
    pub slow_client_disconnects: Counter,
}

/// The static registry: all metric storage preallocated at
/// construction, so recording never observes a missing series.
pub struct Registry {
    shards: Vec<ShardMetrics>,
    /// `devices[shard][gpu]`.
    devices: Vec<Vec<DeviceMetrics>>,
    classes: Vec<ClassMetrics>,
    serving: ServingMetrics,
}

impl Registry {
    /// `device_counts[s]` is shard `s`'s fleet size; `classes` the
    /// workload's flow-class names.
    pub fn new(device_counts: &[usize], classes: &[String]) -> Self {
        Self {
            shards: device_counts.iter().map(|_| ShardMetrics::default()).collect(),
            devices: device_counts
                .iter()
                .map(|&n| (0..n).map(|_| DeviceMetrics::default()).collect())
                .collect(),
            classes: classes
                .iter()
                .map(|name| ClassMetrics {
                    name: name.clone(),
                    completed: Counter::default(),
                    exec_ns: Histogram::default(),
                })
                .collect(),
            serving: ServingMetrics::default(),
        }
    }

    /// The serving-front-end family (event-loop connection counters).
    pub fn serving(&self) -> &ServingMetrics {
        &self.serving
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, s: u32) -> &ShardMetrics {
        &self.shards[s as usize]
    }

    pub fn shards(&self) -> &[ShardMetrics] {
        &self.shards
    }

    /// Per-device slot; `None` for out-of-range ids so callers degrade
    /// to shard-level counters rather than panicking.
    pub fn device(&self, s: u32, gpu: u32) -> Option<&DeviceMetrics> {
        self.devices.get(s as usize)?.get(gpu as usize)
    }

    pub fn class(&self, idx: usize) -> Option<&ClassMetrics> {
        self.classes.get(idx)
    }

    /// Prometheus text exposition (`metrics --format prom`). Rendered
    /// into the caller's buffer; counter families get `# TYPE` lines,
    /// histograms render as summaries with bucket-resolution quantiles.
    pub fn render_prometheus_into(&self, out: &mut String) {
        use std::fmt::Write as _;
        macro_rules! counter_family {
            ($name:literal, $field:ident) => {
                let _ = writeln!(out, "# TYPE {} counter", $name);
                for (s, m) in self.shards.iter().enumerate() {
                    let _ = writeln!(out, "{}{{shard=\"{s}\"}} {}", $name, m.$field.get());
                }
            };
        }
        macro_rules! gauge_family {
            ($name:literal, $field:ident) => {
                let _ = writeln!(out, "# TYPE {} gauge", $name);
                for (s, m) in self.shards.iter().enumerate() {
                    let _ = writeln!(out, "{}{{shard=\"{s}\"}} {}", $name, m.$field.get());
                }
            };
        }
        macro_rules! summary_family {
            ($name:literal, $field:ident) => {
                let _ = writeln!(out, "# TYPE {} summary", $name);
                for (s, m) in self.shards.iter().enumerate() {
                    for (q, label) in [(0.5, "0.5"), (0.99, "0.99"), (0.999, "0.999")] {
                        let _ = writeln!(
                            out,
                            "{}{{shard=\"{s}\",quantile=\"{label}\"}} {}",
                            $name,
                            m.$field.quantile(q)
                        );
                    }
                    let _ = writeln!(out, "{}_sum{{shard=\"{s}\"}} {}", $name, m.$field.sum());
                    let _ =
                        writeln!(out, "{}_count{{shard=\"{s}\"}} {}", $name, m.$field.count());
                }
            };
        }
        counter_family!("mqfq_submitted_total", submitted);
        counter_family!("mqfq_completed_total", completed);
        counter_family!("mqfq_errors_total", errors);
        counter_family!("mqfq_cold_starts_total", cold_starts);
        counter_family!("mqfq_host_warm_starts_total", host_warm_starts);
        counter_family!("mqfq_gpu_warm_starts_total", gpu_warm_starts);
        counter_family!("mqfq_evictions_total", evictions);
        counter_family!("mqfq_evicted_mb_total", evicted_mb);
        counter_family!("mqfq_router_spills_total", spills);
        counter_family!("mqfq_flow_activations_total", flow_activations);
        counter_family!("mqfq_flow_throttles_total", flow_throttles);
        counter_family!("mqfq_flow_deactivations_total", flow_deactivations);
        counter_family!("mqfq_grace_holds_total", grace_holds);
        counter_family!("mqfq_batch_dispatches_total", batch_dispatches);
        counter_family!("mqfq_batched_invocations_total", batched_invocations);
        counter_family!("mqfq_d_resizes_total", d_resizes);
        counter_family!("mqfq_faults_device_total", faults_device);
        counter_family!("mqfq_faults_transient_total", faults_transient);
        counter_family!("mqfq_faults_straggler_total", faults_straggler);
        counter_family!("mqfq_retries_total", retries);
        counter_family!("mqfq_retry_exhausted_total", retry_exhausted);
        counter_family!("mqfq_breaker_trips_total", breaker_trips);
        counter_family!("mqfq_breaker_probes_total", breaker_probes);
        counter_family!("mqfq_shed_total", shed);
        gauge_family!("mqfq_d_tokens", d_tokens);
        gauge_family!("mqfq_global_vt_ns", global_vt_ns);
        gauge_family!("mqfq_est_last_exec_ns", est_last_exec_ns);
        summary_family!("mqfq_est_abs_error_ns", est_abs_error_ns);
        summary_family!("mqfq_queue_wait_ns", queue_wait_ns);
        summary_family!("mqfq_exec_ns", exec_ns);
        summary_family!("mqfq_e2e_ns", e2e_ns);

        let _ = writeln!(out, "# TYPE mqfq_device_dispatches_total counter");
        for (s, devs) in self.devices.iter().enumerate() {
            for (g, d) in devs.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "mqfq_device_dispatches_total{{shard=\"{s}\",gpu=\"{g}\"}} {}",
                    d.dispatches.get()
                );
            }
        }
        let _ = writeln!(out, "# TYPE mqfq_device_cold_starts_total counter");
        for (s, devs) in self.devices.iter().enumerate() {
            for (g, d) in devs.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "mqfq_device_cold_starts_total{{shard=\"{s}\",gpu=\"{g}\"}} {}",
                    d.cold_starts.get()
                );
            }
        }
        let _ = writeln!(out, "# TYPE mqfq_device_evictions_total counter");
        for (s, devs) in self.devices.iter().enumerate() {
            for (g, d) in devs.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "mqfq_device_evictions_total{{shard=\"{s}\",gpu=\"{g}\"}} {}",
                    d.evictions.get()
                );
            }
        }
        let _ = writeln!(out, "# TYPE mqfq_class_completed_total counter");
        for c in &self.classes {
            let _ = writeln!(
                out,
                "mqfq_class_completed_total{{class=\"{}\"}} {}",
                c.name,
                c.completed.get()
            );
        }
        let _ = writeln!(out, "# TYPE mqfq_class_exec_ns summary");
        for c in &self.classes {
            for (q, label) in [(0.5, "0.5"), (0.99, "0.99"), (0.999, "0.999")] {
                let _ = writeln!(
                    out,
                    "mqfq_class_exec_ns{{class=\"{}\",quantile=\"{label}\"}} {}",
                    c.name,
                    c.exec_ns.quantile(q)
                );
            }
        }

        // Serving front end (single unlabeled family).
        let sv = &self.serving;
        let _ = writeln!(out, "# TYPE mqfq_open_connections gauge");
        let _ = writeln!(out, "mqfq_open_connections {}", sv.open_connections.get());
        let _ = writeln!(out, "# TYPE mqfq_accepted_connections_total counter");
        let _ = writeln!(
            out,
            "mqfq_accepted_connections_total {}",
            sv.accepted_connections.get()
        );
        let _ = writeln!(out, "# TYPE mqfq_pipeline_depth summary");
        for (q, label) in [(0.5, "0.5"), (0.99, "0.99"), (0.999, "0.999")] {
            let _ = writeln!(
                out,
                "mqfq_pipeline_depth{{quantile=\"{label}\"}} {}",
                sv.pipeline_depth.quantile(q)
            );
        }
        let _ = writeln!(out, "mqfq_pipeline_depth_sum {}", sv.pipeline_depth.sum());
        let _ = writeln!(
            out,
            "mqfq_pipeline_depth_count {}",
            sv.pipeline_depth.count()
        );
        for (name, c) in [
            ("mqfq_push_subscriptions_total", &sv.push_subscriptions),
            ("mqfq_push_notifications_total", &sv.push_notifications),
            ("mqfq_push_dropped_total", &sv.push_dropped),
            (
                "mqfq_slow_client_disconnects_total",
                &sv.slow_client_disconnects,
            ),
        ] {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        }
    }

    /// JSON exposition (`metrics --format json`) — the same series as
    /// the Prometheus form, shaped for programmatic consumers.
    pub fn to_json(&self) -> Json {
        let shards = self
            .shards
            .iter()
            .enumerate()
            .map(|(s, m)| {
                Json::Obj(vec![
                    ("shard".into(), Json::Int(s as i64)),
                    ("submitted".into(), Json::Int(m.submitted.get() as i64)),
                    ("completed".into(), Json::Int(m.completed.get() as i64)),
                    ("errors".into(), Json::Int(m.errors.get() as i64)),
                    ("cold_starts".into(), Json::Int(m.cold_starts.get() as i64)),
                    (
                        "host_warm_starts".into(),
                        Json::Int(m.host_warm_starts.get() as i64),
                    ),
                    (
                        "gpu_warm_starts".into(),
                        Json::Int(m.gpu_warm_starts.get() as i64),
                    ),
                    ("evictions".into(), Json::Int(m.evictions.get() as i64)),
                    ("evicted_mb".into(), Json::Int(m.evicted_mb.get() as i64)),
                    ("spills".into(), Json::Int(m.spills.get() as i64)),
                    (
                        "flow_activations".into(),
                        Json::Int(m.flow_activations.get() as i64),
                    ),
                    (
                        "flow_throttles".into(),
                        Json::Int(m.flow_throttles.get() as i64),
                    ),
                    (
                        "flow_deactivations".into(),
                        Json::Int(m.flow_deactivations.get() as i64),
                    ),
                    ("grace_holds".into(), Json::Int(m.grace_holds.get() as i64)),
                    (
                        "batch_dispatches".into(),
                        Json::Int(m.batch_dispatches.get() as i64),
                    ),
                    (
                        "batched_invocations".into(),
                        Json::Int(m.batched_invocations.get() as i64),
                    ),
                    ("d_resizes".into(), Json::Int(m.d_resizes.get() as i64)),
                    (
                        "faults_device".into(),
                        Json::Int(m.faults_device.get() as i64),
                    ),
                    (
                        "faults_transient".into(),
                        Json::Int(m.faults_transient.get() as i64),
                    ),
                    (
                        "faults_straggler".into(),
                        Json::Int(m.faults_straggler.get() as i64),
                    ),
                    ("retries".into(), Json::Int(m.retries.get() as i64)),
                    (
                        "retry_exhausted".into(),
                        Json::Int(m.retry_exhausted.get() as i64),
                    ),
                    (
                        "breaker_trips".into(),
                        Json::Int(m.breaker_trips.get() as i64),
                    ),
                    (
                        "breaker_probes".into(),
                        Json::Int(m.breaker_probes.get() as i64),
                    ),
                    ("shed".into(), Json::Int(m.shed.get() as i64)),
                    ("d_tokens".into(), Json::Int(m.d_tokens.get())),
                    ("global_vt_ns".into(), Json::Int(m.global_vt_ns.get())),
                    (
                        "est_last_exec_ns".into(),
                        Json::Int(m.est_last_exec_ns.get()),
                    ),
                    ("est_abs_error_ns".into(), m.est_abs_error_ns.to_json()),
                    ("queue_wait_ns".into(), m.queue_wait_ns.to_json()),
                    ("exec_ns".into(), m.exec_ns.to_json()),
                    ("e2e_ns".into(), m.e2e_ns.to_json()),
                ])
            })
            .collect();
        let devices = self
            .devices
            .iter()
            .enumerate()
            .flat_map(|(s, devs)| {
                devs.iter().enumerate().map(move |(g, d)| {
                    Json::Obj(vec![
                        ("shard".into(), Json::Int(s as i64)),
                        ("gpu".into(), Json::Int(g as i64)),
                        ("dispatches".into(), Json::Int(d.dispatches.get() as i64)),
                        ("cold_starts".into(), Json::Int(d.cold_starts.get() as i64)),
                        ("evictions".into(), Json::Int(d.evictions.get() as i64)),
                    ])
                })
            })
            .collect();
        let classes = self
            .classes
            .iter()
            .map(|c| {
                Json::Obj(vec![
                    ("class".into(), Json::str(c.name.clone())),
                    ("completed".into(), Json::Int(c.completed.get() as i64)),
                    ("exec_ns".into(), c.exec_ns.to_json()),
                ])
            })
            .collect();
        let sv = &self.serving;
        let serving = Json::Obj(vec![
            (
                "open_connections".into(),
                Json::Int(sv.open_connections.get()),
            ),
            (
                "accepted_connections".into(),
                Json::Int(sv.accepted_connections.get() as i64),
            ),
            ("pipeline_depth".into(), sv.pipeline_depth.to_json()),
            (
                "push_subscriptions".into(),
                Json::Int(sv.push_subscriptions.get() as i64),
            ),
            (
                "push_notifications".into(),
                Json::Int(sv.push_notifications.get() as i64),
            ),
            (
                "push_dropped".into(),
                Json::Int(sv.push_dropped.get() as i64),
            ),
            (
                "slow_client_disconnects".into(),
                Json::Int(sv.slow_client_disconnects.get() as i64),
            ),
        ]);
        Json::Obj(vec![
            ("schema".into(), Json::str("mqfq-metrics/v1")),
            ("shards".into(), Json::Arr(shards)),
            ("devices".into(), Json::Arr(devices)),
            ("classes".into(), Json::Arr(classes)),
            ("serving".into(), serving),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(-3);
        assert_eq!(g.get(), -3);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), 0, "empty histogram reports 0");
        // 90 fast (≤ 1023 ns), 9 medium (≤ 65535 ns), 1 slow (~1 ms).
        for _ in 0..90 {
            h.record(1_000);
        }
        for _ in 0..9 {
            h.record(60_000);
        }
        h.record(1_000_000);
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 90 * 1_000 + 9 * 60_000 + 1_000_000);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        let p999 = h.quantile(0.999);
        // Bucket resolution: p50 lands in 1000's bucket [512,1023],
        // p99 in 60000's bucket, p999 in the 1ms bucket.
        assert_eq!(p50, 1023);
        assert!((32_768..=65_535).contains(&p99), "p99={p99}");
        assert!(p999 >= 1_000_000 / 2 && p999 >= p99, "p999={p999}");
        assert!((h.mean() - h.sum() as f64 / 100.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_edge_values() {
        let h = Histogram::default();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.25), 0);
        assert_eq!(h.quantile(1.0), u64::MAX);
    }

    #[test]
    fn registry_renders_both_forms() {
        let r = Registry::new(&[2, 1], &["isoneural".into(), "fft".into()]);
        r.shard(0).submitted.add(3);
        r.shard(0).completed.add(3);
        r.shard(1).submitted.add(1);
        r.shard(0).e2e_ns.record(5_000);
        r.shard(0).grace_holds.add(2);
        r.shard(0).batch_dispatches.inc();
        r.shard(0).batched_invocations.add(3);
        r.shard(0).d_resizes.inc();
        r.shard(0).faults_transient.add(4);
        r.shard(0).retries.add(3);
        r.shard(0).retry_exhausted.inc();
        r.shard(0).breaker_trips.inc();
        r.shard(0).shed.add(2);
        r.shard(0).est_abs_error_ns.record(250);
        r.shard(0).est_last_exec_ns.set(1_500);
        r.device(0, 1).unwrap().dispatches.inc();
        assert!(r.device(0, 5).is_none());
        assert!(r.device(9, 0).is_none());
        r.class(0).unwrap().completed.add(2);
        r.serving().accepted_connections.add(7);
        r.serving().open_connections.set(5);
        r.serving().pipeline_depth.record(16);
        r.serving().push_subscriptions.inc();
        r.serving().push_notifications.inc();
        r.serving().push_dropped.inc();
        r.serving().slow_client_disconnects.inc();

        let mut prom = String::new();
        r.render_prometheus_into(&mut prom);
        assert!(prom.contains("# TYPE mqfq_submitted_total counter"), "{prom}");
        assert!(prom.contains("mqfq_submitted_total{shard=\"0\"} 3"), "{prom}");
        assert!(prom.contains("mqfq_submitted_total{shard=\"1\"} 1"), "{prom}");
        assert!(
            prom.contains("mqfq_e2e_ns{shard=\"0\",quantile=\"0.99\"}"),
            "{prom}"
        );
        assert!(
            prom.contains("mqfq_device_dispatches_total{shard=\"0\",gpu=\"1\"} 1"),
            "{prom}"
        );
        assert!(
            prom.contains("mqfq_class_completed_total{class=\"isoneural\"} 2"),
            "{prom}"
        );
        assert!(prom.contains("mqfq_grace_holds_total{shard=\"0\"} 2"), "{prom}");
        assert!(
            prom.contains("mqfq_batch_dispatches_total{shard=\"0\"} 1"),
            "{prom}"
        );
        assert!(
            prom.contains("mqfq_batched_invocations_total{shard=\"0\"} 3"),
            "{prom}"
        );
        assert!(prom.contains("mqfq_d_resizes_total{shard=\"0\"} 1"), "{prom}");
        assert!(
            prom.contains("mqfq_faults_transient_total{shard=\"0\"} 4"),
            "{prom}"
        );
        assert!(prom.contains("mqfq_retries_total{shard=\"0\"} 3"), "{prom}");
        assert!(
            prom.contains("mqfq_retry_exhausted_total{shard=\"0\"} 1"),
            "{prom}"
        );
        assert!(
            prom.contains("mqfq_breaker_trips_total{shard=\"0\"} 1"),
            "{prom}"
        );
        assert!(prom.contains("mqfq_shed_total{shard=\"0\"} 2"), "{prom}");
        assert!(
            prom.contains("mqfq_est_last_exec_ns{shard=\"0\"} 1500"),
            "{prom}"
        );
        assert!(prom.contains("mqfq_est_abs_error_ns_count{shard=\"0\"} 1"), "{prom}");

        assert!(prom.contains("mqfq_open_connections 5"), "{prom}");
        assert!(prom.contains("mqfq_accepted_connections_total 7"), "{prom}");
        assert!(prom.contains("mqfq_pipeline_depth_count 1"), "{prom}");
        assert!(prom.contains("mqfq_push_subscriptions_total 1"), "{prom}");
        assert!(prom.contains("mqfq_push_notifications_total 1"), "{prom}");
        assert!(prom.contains("mqfq_push_dropped_total 1"), "{prom}");
        assert!(
            prom.contains("mqfq_slow_client_disconnects_total 1"),
            "{prom}"
        );

        let doc = r.to_json().render();
        assert!(doc.contains("mqfq-metrics/v1"), "{doc}");
        assert!(doc.contains("\"submitted\": 3"), "{doc}");
        assert!(doc.contains("\"grace_holds\": 2"), "{doc}");
        assert!(doc.contains("\"batched_invocations\": 3"), "{doc}");
        assert!(doc.contains("\"d_resizes\": 1"), "{doc}");
        assert!(doc.contains("\"faults_transient\": 4"), "{doc}");
        assert!(doc.contains("\"retries\": 3"), "{doc}");
        assert!(doc.contains("\"shed\": 2"), "{doc}");
        assert!(doc.contains("\"est_last_exec_ns\": 1500"), "{doc}");
        assert!(doc.contains("\"class\": \"fft\""), "{doc}");
        assert!(doc.contains("\"open_connections\": 5"), "{doc}");
        assert!(doc.contains("\"slow_client_disconnects\": 1"), "{doc}");
    }
}
