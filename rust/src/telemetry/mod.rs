//! §Observability: the telemetry subsystem — a lock-free metrics
//! registry ([`registry`]), ring-buffered invocation lifecycle tracing
//! ([`trace`]), and the export surfaces behind the `metrics` / `trace`
//! wire verbs and the `replay --trace-out` JSONL sink.
//!
//! One [`Telemetry`] instance is shared (via `Arc`) by every layer of
//! one system instance: each shard's [`crate::plane::ControlPlane`]
//! emits the invocation lifecycle, [`crate::cluster::Cluster`] and
//! [`crate::server`] add routing/membership events, and the wire layer
//! exports everything. Sim and wire runs attach the *same* subsystem,
//! so both emit the same event vocabulary and sim-vs-wire divergence
//! is a line-diffable artifact.
//!
//! ## Event vocabulary
//!
//! Events carry fixed ids (`inv`, `func`, `shard`) plus three
//! kind-specific payload words `a`/`b`/`c`:
//!
//! | kind         | a                                   | b               | c    |
//! |--------------|-------------------------------------|-----------------|------|
//! | `submit`     | —                                   | —               | —    |
//! | `route`      | shard epoch                         | spill (0/1)     | —    |
//! | `enqueue`    | flow VT, virtual ns                 | Global_VT, ns   | —    |
//! | `dispatch`   | start kind (0 cold, 1 host, 2 gpu)  | boot ns         | gpu  |
//! | `exec_start` | blocking (queue-induced delay) ns   | —               | gpu  |
//! | `complete`   | end-to-end ns                       | exec ns         | gpu  |
//! | `error`      | —                                   | —               | —    |
//! | `flow_state` | state (0 active, 1 throttled, 2 inactive) | —         | —    |
//! | `global_vt`  | Global_VT, virtual ns               | —               | —    |
//! | `d_tokens`   | tokens in use                       | current limit D | —    |
//! | `evict`      | megabytes moved                     | —               | gpu  |
//! | `epoch`      | new epoch                           | tickets lost    | —    |
//! | `grace`      | grace window ns                     | predicted IAT ns | —   |
//! | `batch`      | invocations coalesced               | VT advance, virtual ns | — |
//! | `d_resize`   | new D                               | old D           | demand ×1e3 |
//! | `estimate`   | predicted exec ns                   | actual exec ns  | gpu  |
//! | `fault`      | kind (0 device, 1 transient, 2 straggler) | attempt index | gpu |
//! | `requeue`    | attempts consumed so far            | —               | —    |
//! | `breaker_state` | state (0 closed, 1 open, 2 half-open) | —          | —    |
//! | `shed`       | predicted wait ns                   | retry-after ms  | —    |
//!
//! The per-invocation lifecycle reads `submit → [route] → enqueue →
//! dispatch → exec_start → complete|error` (`route` appears only on
//! sharded runs; the plane assigns the invocation id at enqueue, so a
//! cluster's `route` event is keyed by function and timestamp).
//!
//! ## Overhead model
//!
//! * A counter/gauge record is one `Relaxed` atomic RMW (~ns, no
//!   fences on x86); a histogram record is a bit-scan plus three.
//! * A trace push copies one 64-byte `Copy` struct into a preallocated
//!   ring slot under a plain mutex whose critical section is shorter
//!   than the plane lock the producer already holds.
//! * Nothing on the record path allocates — `tests/alloc_churn.rs`
//!   proves zero heap events steady-state with a counting global
//!   allocator — and `experiments/perf.rs` benches instrumented vs
//!   bare dispatch with a release gate at +10%.
//! * Detached (`Option::None`) telemetry costs one branch per site.
//!
//! ## Adding a metric
//!
//! 1. Add the `Counter`/`Gauge`/`Histogram` field to the right family
//!    in [`registry`] (`ShardMetrics`, `DeviceMetrics`, `ClassMetrics`,
//!    or the serving-front-end `ServingMetrics`) — storage is
//!    preallocated, so no registration call exists to forget.
//! 2. Record it from the owning layer via [`ShardSink`] (planes) or
//!    the shared [`Telemetry`] handle (cluster/server).
//! 3. Add it to both exports in [`registry`]
//!    (`render_prometheus_into` + `to_json`) — the smoke test's
//!    conservation checks read the JSON form.

pub mod registry;
pub mod trace;

use std::sync::Arc;

pub use registry::{
    ClassMetrics, Counter, DeviceMetrics, Gauge, Histogram, Registry, ServingMetrics,
    ShardMetrics,
};
pub use trace::{EventKind, TraceEvent, TraceRing, ALL_KINDS, NO_FUNC, NO_INV};

use crate::types::{Nanos, StartKind};
use crate::util::json::Json;

/// Default trace-ring capacity (events). At ~6 lifecycle events per
/// invocation this buffers ~10k invocations; sized for introspection,
/// not archival — overflow drops oldest and counts.
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// Stable payload encoding of [`StartKind`] in `dispatch` events.
pub fn start_kind_code(k: StartKind) -> i64 {
    match k {
        StartKind::Cold => 0,
        StartKind::HostWarm => 1,
        StartKind::GpuWarm => 2,
    }
}

/// `flow_state` payload encoding of [`crate::scheduler::QState`].
pub fn qstate_code(s: crate::scheduler::QState) -> i64 {
    match s {
        crate::scheduler::QState::Active => 0,
        crate::scheduler::QState::Throttled => 1,
        crate::scheduler::QState::Inactive => 2,
    }
}

/// A workload's flow-class table: unique class names in first-seen
/// order, plus the `FuncId → class index` map a [`ShardSink`] records
/// with. Every shard of a cluster shares one workload, so one call
/// sizes the registry and every sink.
pub fn workload_classes(w: &crate::workload::Workload) -> (Vec<String>, Vec<u32>) {
    let mut names: Vec<String> = Vec::new();
    let mut class_of = Vec::with_capacity(w.len());
    for f in &w.funcs {
        let idx = match names.iter().position(|n| n == f.class.name) {
            Some(i) => i,
            None => {
                names.push(f.class.name.to_string());
                names.len() - 1
            }
        };
        class_of.push(idx as u32);
    }
    (names, class_of)
}

/// One system instance's telemetry: the static metrics registry plus
/// the shared trace ring.
pub struct Telemetry {
    pub registry: Registry,
    pub trace: TraceRing,
}

impl Telemetry {
    /// `device_counts[s]` = shard `s`'s fleet size; `classes` = the
    /// workload's flow-class names (the per-class series).
    pub fn new(device_counts: &[usize], classes: &[String]) -> Self {
        Self::with_ring_capacity(device_counts, classes, DEFAULT_RING_CAPACITY)
    }

    pub fn with_ring_capacity(
        device_counts: &[usize],
        classes: &[String],
        ring_capacity: usize,
    ) -> Self {
        Self {
            registry: Registry::new(device_counts, classes),
            trace: TraceRing::new(ring_capacity),
        }
    }

    /// Push one trace event (stamps its sequence number).
    #[inline]
    pub fn emit(&self, ev: TraceEvent) {
        self.trace.push(ev);
    }

    pub fn dropped_events(&self) -> u64 {
        self.trace.dropped_events()
    }

    /// Prometheus text exposition, including the ring-loss counter.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        self.registry.render_prometheus_into(&mut out);
        use std::fmt::Write as _;
        let _ = writeln!(out, "# TYPE mqfq_trace_dropped_events_total counter");
        let _ = writeln!(out, "mqfq_trace_dropped_events_total {}", self.dropped_events());
        out
    }

    /// JSON exposition, including the ring-loss counter.
    pub fn to_json(&self) -> Json {
        let Json::Obj(mut fields) = self.registry.to_json() else {
            unreachable!("registry JSON is an object");
        };
        fields.push((
            "trace_dropped_events".into(),
            Json::Int(self.dropped_events() as i64),
        ));
        Json::Obj(fields)
    }
}

/// A shard-scoped emission handle: the `Arc<Telemetry>` plus this
/// shard's index and the workload's function→class mapping, so the
/// plane's hot path resolves its metric slots without lookups.
pub struct ShardSink {
    tel: Arc<Telemetry>,
    shard: u32,
    /// `class_of[func]` → index into the registry's class table
    /// (`NO_FUNC` when the function has no registered class).
    class_of: Vec<u32>,
}

impl ShardSink {
    pub fn new(tel: Arc<Telemetry>, shard: u32, class_of: Vec<u32>) -> Self {
        Self {
            tel,
            shard,
            class_of,
        }
    }

    pub fn shard_id(&self) -> u32 {
        self.shard
    }

    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.tel
    }

    #[inline]
    pub fn metrics(&self) -> &ShardMetrics {
        self.tel.registry.shard(self.shard)
    }

    #[inline]
    pub fn device(&self, gpu: u32) -> Option<&DeviceMetrics> {
        self.tel.registry.device(self.shard, gpu)
    }

    #[inline]
    pub fn class(&self, func: u32) -> Option<&ClassMetrics> {
        let idx = *self.class_of.get(func as usize)?;
        self.tel.registry.class(idx as usize)
    }

    /// Start an event pre-stamped with this shard's index.
    #[inline]
    pub fn event(&self, at: Nanos, kind: EventKind) -> TraceEvent {
        TraceEvent::new(at, kind, self.shard)
    }

    #[inline]
    pub fn emit(&self, ev: TraceEvent) {
        self.tel.emit(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn telemetry_facade_exports_both_forms() {
        let t = Telemetry::with_ring_capacity(&[1], &["fft".into()], 4);
        t.registry.shard(0).submitted.inc();
        for i in 0..6 {
            t.emit(TraceEvent::new(i, EventKind::Submit, 0));
        }
        assert_eq!(t.dropped_events(), 2);
        let prom = t.render_prometheus();
        assert!(prom.contains("mqfq_trace_dropped_events_total 2"), "{prom}");
        let doc = t.to_json().render();
        assert!(doc.contains("\"trace_dropped_events\": 2"), "{doc}");
    }

    #[test]
    fn shard_sink_resolves_slots() {
        let t = Arc::new(Telemetry::new(&[2, 2], &["a".into(), "b".into()]));
        // Funcs 0,1 map to class 1; func 2 has no class.
        let sink = ShardSink::new(t.clone(), 1, vec![1, 1, NO_FUNC]);
        sink.metrics().completed.inc();
        assert_eq!(t.registry.shard(1).completed.get(), 1);
        assert_eq!(t.registry.shard(0).completed.get(), 0);
        sink.class(0).unwrap().completed.inc();
        assert_eq!(t.registry.class(1).unwrap().completed.get(), 1);
        assert!(sink.class(2).is_none());
        assert!(sink.class(9).is_none());
        assert!(sink.device(1).is_some());
        assert!(sink.device(5).is_none());
        sink.emit(sink.event(7, EventKind::GlobalVt).a(42));
        let evs = t.trace.drain(10);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].shard, 1);
        assert_eq!(evs[0].a, 42);
    }

    #[test]
    fn payload_codes_are_stable() {
        use crate::scheduler::QState;
        assert_eq!(start_kind_code(StartKind::Cold), 0);
        assert_eq!(start_kind_code(StartKind::HostWarm), 1);
        assert_eq!(start_kind_code(StartKind::GpuWarm), 2);
        assert_eq!(qstate_code(QState::Active), 0);
        assert_eq!(qstate_code(QState::Throttled), 1);
        assert_eq!(qstate_code(QState::Inactive), 2);
    }
}
