//! Discrete-event engine: replays an open-loop trace through the
//! control plane under virtual time. Hour-scale paper experiments run
//! in milliseconds of wall time here, with the *same* control-plane
//! code the real-time driver uses.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::plane::{ControlPlane, Dispatch, PlaneConfig};
use crate::types::{InvocationId, Nanos};
use crate::workload::{Trace, Workload};

/// Engine event. Ordering: time, then kind (completions before ticks
/// before touches at the same instant), then sequence for determinism.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EvKind {
    Complete(InvocationId),
    /// Exact utilization-integral touch at an exec start.
    Touch,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Ev {
    at: Nanos,
    seq: u64,
    kind: EvKind,
}

/// Replay outcome.
pub struct ReplayResult {
    pub plane: ControlPlane,
    /// Virtual time when the last invocation completed.
    pub makespan: Nanos,
    /// Mean device utilization over the run (exact integral).
    pub mean_util: f64,
    /// Events processed (sim-engine throughput metric).
    pub events: u64,
}

impl ReplayResult {
    pub fn recorder(&self) -> &crate::metrics::Recorder {
        &self.plane.recorder
    }
}

/// Replay `trace` over `workload` under `cfg`.
///
/// Runs until every arrival has been ingested and every dispatched
/// invocation completed. Monitor ticks fire on the configured cadence
/// whenever work is pending or in flight.
pub fn replay(workload: Workload, trace: &Trace, cfg: PlaneConfig) -> ReplayResult {
    let monitor_period = cfg.monitor_period.max(1);
    let mut plane = ControlPlane::new(workload, cfg);
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut seq: u64 = 0;
    let mut next_arrival = 0usize;
    let mut next_tick: Nanos = monitor_period;
    let mut makespan: Nanos = 0;
    let mut events: u64 = 0;

    let push = |heap: &mut BinaryHeap<Reverse<Ev>>, seq: &mut u64, at: Nanos, kind: EvKind| {
        *seq += 1;
        heap.push(Reverse(Ev { at, seq: *seq, kind }));
    };

    let schedule_dispatches = |heap: &mut BinaryHeap<Reverse<Ev>>,
                                   seq: &mut u64,
                                   ds: &[Dispatch]| {
        for d in ds {
            if d.exec_start > d.at {
                push(heap, seq, d.exec_start, EvKind::Touch);
            }
            push(heap, seq, d.complete_at, EvKind::Complete(d.inv));
        }
    };

    loop {
        // Next event: earliest of pending trace arrival vs heap.
        let arrival_at = trace.events.get(next_arrival).map(|e| e.at);
        let heap_at = heap.peek().map(|Reverse(e)| e.at);
        let busy = plane.in_flight() > 0 || plane.pending() > 0;

        // Monitor ticks only while the system has work (otherwise an
        // idle server would tick forever).
        let tick_at = if busy { Some(next_tick) } else { None };

        let candidates = [arrival_at, heap_at, tick_at];
        let Some(now) = candidates.iter().flatten().min().copied() else {
            break; // fully drained
        };
        events += 1;
        // Runaway guard: a scheduling deadlock would otherwise tick
        // forever in virtual time. Fail loudly instead.
        assert!(
            events < 500_000_000,
            "sim runaway: {} pending, {} in flight at t={}s",
            plane.pending(),
            plane.in_flight(),
            crate::types::to_secs(now)
        );

        if tick_at == Some(now) && arrival_at.map(|t| t > now).unwrap_or(true)
            && heap_at.map(|t| t > now).unwrap_or(true)
        {
            let ds = plane.on_monitor_tick(now);
            schedule_dispatches(&mut heap, &mut seq, &ds);
            next_tick = now + monitor_period;
            continue;
        }

        if arrival_at == Some(now) && heap_at.map(|t| t >= now).unwrap_or(true) {
            let ev = trace.events[next_arrival];
            next_arrival += 1;
            let (_, ds) = plane.on_arrival(ev.func, now);
            schedule_dispatches(&mut heap, &mut seq, &ds);
            continue;
        }

        let Reverse(ev) = heap.pop().unwrap();
        match ev.kind {
            EvKind::Complete(inv) => {
                let ds = plane.on_complete(inv, ev.at);
                makespan = makespan.max(ev.at);
                schedule_dispatches(&mut heap, &mut seq, &ds);
            }
            EvKind::Touch => plane.touch(ev.at),
        }
    }

    let mean_util = plane.mean_utilization(makespan.max(1));
    ReplayResult {
        plane,
        makespan,
        mean_util,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::policies::PolicyKind;
    use crate::types::{secs, FuncId};
    use crate::workload::catalog::by_name;
    use crate::workload::trace::TraceEvent;

    fn tiny_workload() -> (Workload, Trace) {
        let mut w = Workload::default();
        let a = w.register(by_name("fft").unwrap(), 0, 1.0);
        let b = w.register(by_name("isoneural").unwrap(), 0, 1.0);
        let mut t = Trace::default();
        for i in 0..20 {
            t.events.push(TraceEvent {
                at: secs(i as f64 * 0.8),
                func: if i % 2 == 0 { a } else { b },
            });
        }
        t.sort();
        (w, t)
    }

    #[test]
    fn replay_completes_every_invocation() {
        let (w, t) = tiny_workload();
        let r = replay(w, &t, PlaneConfig::default());
        assert_eq!(r.recorder().len(), 20);
        assert!(r.makespan > 0);
        assert_eq!(r.plane.in_flight(), 0);
        assert_eq!(r.plane.pending(), 0);
    }

    #[test]
    fn replay_is_deterministic() {
        let (w, t) = tiny_workload();
        let r1 = replay(w.clone(), &t, PlaneConfig::default());
        let r2 = replay(w, &t, PlaneConfig::default());
        assert_eq!(r1.recorder().len(), r2.recorder().len());
        assert!(
            (r1.recorder().weighted_avg_latency_s()
                - r2.recorder().weighted_avg_latency_s())
            .abs()
                < 1e-12
        );
        assert_eq!(r1.makespan, r2.makespan);
    }

    #[test]
    fn latencies_are_causal() {
        let (w, t) = tiny_workload();
        let r = replay(w, &t, PlaneConfig::default());
        for rec in &r.recorder().records {
            assert!(rec.dispatched >= rec.arrived);
            assert!(rec.completed > rec.dispatched);
        }
    }

    #[test]
    fn fcfs_and_mqfq_both_run() {
        let (w, t) = tiny_workload();
        for kind in [PolicyKind::Fcfs, PolicyKind::Mqfq, PolicyKind::Batch] {
            let cfg = PlaneConfig {
                policy: kind,
                ..Default::default()
            };
            let r = replay(w.clone(), &t, cfg);
            assert_eq!(r.recorder().len(), 20, "{}", kind.name());
        }
    }

    #[test]
    fn warm_starts_dominate_after_first_wave() {
        let (w, t) = tiny_workload();
        let r = replay(w, &t, PlaneConfig::default());
        let stats = r.plane.pool_stats();
        assert!(stats.cold <= 4, "too many colds: {stats:?}");
        assert!(stats.gpu_warm + stats.host_warm >= 16);
    }

    #[test]
    fn utilization_positive_under_load() {
        let (w, t) = tiny_workload();
        let r = replay(w, &t, PlaneConfig::default());
        assert!(r.mean_util > 0.05, "{}", r.mean_util);
        assert!(r.mean_util <= 1.0);
    }

    #[test]
    fn higher_load_increases_latency() {
        let mut w = Workload::default();
        let f = w.register(by_name("lud").unwrap(), 0, 1.0);
        let mk = |iat: f64| {
            let mut t = Trace::default();
            for i in 0..30 {
                t.events.push(TraceEvent {
                    at: secs(i as f64 * iat),
                    func: f,
                });
            }
            t
        };
        let light = replay(w.clone(), &mk(5.0), PlaneConfig::default());
        let heavy = replay(w, &mk(0.5), PlaneConfig::default());
        assert!(
            heavy.recorder().weighted_avg_latency_s()
                > light.recorder().weighted_avg_latency_s()
        );
    }

    #[test]
    fn funcid_out_of_range_is_rejected_by_debug_build() {
        // Guard: a trace referencing an unknown function would index out
        // of bounds — Trace::load validates this; replay assumes valid.
        let (w, mut t) = tiny_workload();
        t.events.truncate(1);
        t.events[0].func = FuncId(1); // valid
        let r = replay(w, &t, PlaneConfig::default());
        assert_eq!(r.recorder().len(), 1);
    }
}
