//! Discrete-event engine: replays an open-loop trace through a control
//! plane — or a sharded [`Cluster`](crate::cluster::Cluster) — under
//! virtual time. Hour-scale paper experiments run in milliseconds of
//! wall time here, with the *same* control-plane code the real-time
//! driver uses.
//!
//! The engine is generic over [`SimTarget`]: the single-server
//! [`replay`] and the multi-shard [`replay_cluster`] share one event
//! loop, so a 1-shard cluster is event-for-event identical to a plain
//! plane replay by construction (property-tested in
//! `rust/tests/prop_cluster.rs`). All shards advance on one global
//! virtual clock; per-shard completions, touches, and monitor ticks are
//! totally ordered by a stable (time, sequence) key, which is what
//! makes multi-shard replays deterministic.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::{Cluster, ClusterConfig};
use crate::plane::{ControlPlane, Dispatch, PlaneConfig};
use crate::types::{DurNanos, FuncId, InvocationId, Nanos};
use crate::workload::{Trace, Workload};

/// Engine event. Ordering: time, then sequence (unique — assigned in
/// scheduling order, so same-instant events replay in the order their
/// causes were processed), then kind for completeness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EvKind {
    /// Completion of (shard, invocation, attempt) — attempt-stamped so
    /// a completion left over from a faulted, re-queued attempt is
    /// dropped by the plane instead of double-freeing the retry.
    Complete(usize, InvocationId, u32),
    /// Exact utilization-integral touch at an exec start, per shard.
    Touch(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Ev {
    at: Nanos,
    seq: u64,
    kind: EvKind,
}

/// One dispatch decision tagged with the shard that made it (shard 0
/// always, for a plain control plane).
#[derive(Debug, Clone, Copy)]
pub struct ShardDispatch {
    pub shard: usize,
    pub dispatch: Dispatch,
}

/// Anything the engine can drive on one global virtual clock: a single
/// [`ControlPlane`] (every shard index is 0) or a [`Cluster`] of them.
///
/// The contract mirrors the plane's clock-agnostic entry points;
/// implementations must be deterministic functions of the call sequence.
pub trait SimTarget {
    /// Work pending or in flight anywhere (monitor ticks fire only then).
    fn busy(&self) -> bool;
    fn sim_arrival(&mut self, func: FuncId, now: Nanos) -> Vec<ShardDispatch>;
    fn sim_complete(
        &mut self,
        shard: usize,
        inv: InvocationId,
        attempt: u32,
        now: Nanos,
    ) -> Vec<ShardDispatch>;
    fn sim_tick(&mut self, now: Nanos) -> Vec<ShardDispatch>;
    fn sim_touch(&mut self, shard: usize, now: Nanos);
    /// (pending, in_flight) totals, for the runaway diagnostic.
    fn sim_load(&self) -> (usize, usize);
}

impl SimTarget for ControlPlane {
    fn busy(&self) -> bool {
        self.in_flight() > 0 || self.pending() > 0
    }

    fn sim_arrival(&mut self, func: FuncId, now: Nanos) -> Vec<ShardDispatch> {
        let (_, ds) = self.on_arrival(func, now);
        crate::cluster::tag(0, ds)
    }

    fn sim_complete(
        &mut self,
        _shard: usize,
        inv: InvocationId,
        attempt: u32,
        now: Nanos,
    ) -> Vec<ShardDispatch> {
        crate::cluster::tag(0, self.on_complete_attempt(inv, attempt, now).1)
    }

    fn sim_tick(&mut self, now: Nanos) -> Vec<ShardDispatch> {
        crate::cluster::tag(0, self.on_monitor_tick(now))
    }

    fn sim_touch(&mut self, _shard: usize, now: Nanos) {
        self.touch(now);
    }

    fn sim_load(&self) -> (usize, usize) {
        (self.pending(), self.in_flight())
    }
}

/// The shared event loop. Runs until every arrival has been ingested
/// and every dispatched invocation completed; monitor ticks fire on the
/// configured cadence whenever work is pending or in flight. Returns
/// (makespan, events processed).
fn drive<T: SimTarget>(target: &mut T, trace: &Trace, monitor_period: DurNanos) -> (Nanos, u64) {
    let monitor_period = monitor_period.max(1);
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut seq: u64 = 0;
    let mut next_arrival = 0usize;
    let mut next_tick: Nanos = monitor_period;
    let mut makespan: Nanos = 0;
    let mut events: u64 = 0;

    let push = |heap: &mut BinaryHeap<Reverse<Ev>>, seq: &mut u64, at: Nanos, kind: EvKind| {
        *seq += 1;
        heap.push(Reverse(Ev { at, seq: *seq, kind }));
    };

    let schedule_dispatches = |heap: &mut BinaryHeap<Reverse<Ev>>,
                                   seq: &mut u64,
                                   ds: &[ShardDispatch]| {
        for sd in ds {
            let d = sd.dispatch;
            if d.exec_start > d.at {
                push(heap, seq, d.exec_start, EvKind::Touch(sd.shard));
            }
            push(
                heap,
                seq,
                d.complete_at,
                EvKind::Complete(sd.shard, d.inv, d.attempt),
            );
        }
    };

    loop {
        // Next event: earliest of pending trace arrival vs heap.
        let arrival_at = trace.events.get(next_arrival).map(|e| e.at);
        let heap_at = heap.peek().map(|Reverse(e)| e.at);
        let busy = target.busy();

        // Monitor ticks only while the system has work (otherwise an
        // idle server would tick forever). When busyness resumes after
        // an idle gap, the arrival handler below fast-forwards
        // `next_tick` past the resume instant, so post-idle ticks fire
        // at current virtual time instead of the stale cadence the
        // original seed engine kept.
        let tick_at = if busy { Some(next_tick) } else { None };

        let candidates = [arrival_at, heap_at, tick_at];
        let Some(now) = candidates.iter().flatten().min().copied() else {
            break; // fully drained
        };
        events += 1;
        // Runaway guard: a scheduling deadlock would otherwise tick
        // forever in virtual time. Fail loudly instead.
        #[allow(clippy::manual_assert)]
        if events >= 500_000_000 {
            let (pending, in_flight) = target.sim_load();
            panic!(
                "sim runaway: {pending} pending, {in_flight} in flight at t={}s",
                crate::types::to_secs(now)
            );
        }

        if tick_at == Some(now) && arrival_at.map(|t| t > now).unwrap_or(true)
            && heap_at.map(|t| t > now).unwrap_or(true)
        {
            let ds = target.sim_tick(now);
            schedule_dispatches(&mut heap, &mut seq, &ds);
            next_tick = now + monitor_period;
            continue;
        }

        if arrival_at == Some(now) && heap_at.map(|t| t >= now).unwrap_or(true) {
            // Busyness resumes with this arrival (only arrivals can wake
            // an idle system): re-sync the monitor cadence so the next
            // tick fires after `now`, not at the virtual time the clock
            // had when the system went idle. Phase-preserving: advance
            // in whole periods past `now`.
            if !busy && next_tick < now {
                let behind = (now - next_tick) / monitor_period + 1;
                next_tick += behind * monitor_period;
            }
            let ev = trace.events[next_arrival];
            next_arrival += 1;
            let ds = target.sim_arrival(ev.func, now);
            schedule_dispatches(&mut heap, &mut seq, &ds);
            continue;
        }

        let Reverse(ev) = heap.pop().unwrap();
        match ev.kind {
            EvKind::Complete(shard, inv, attempt) => {
                let ds = target.sim_complete(shard, inv, attempt, ev.at);
                makespan = makespan.max(ev.at);
                schedule_dispatches(&mut heap, &mut seq, &ds);
            }
            EvKind::Touch(shard) => target.sim_touch(shard, ev.at),
        }
    }

    (makespan, events)
}

/// Replay outcome.
pub struct ReplayResult {
    pub plane: ControlPlane,
    /// Virtual time when the last invocation completed.
    pub makespan: Nanos,
    /// Mean device utilization over the run (exact integral).
    pub mean_util: f64,
    /// Events processed (sim-engine throughput metric).
    pub events: u64,
}

impl ReplayResult {
    pub fn recorder(&self) -> &crate::metrics::Recorder {
        &self.plane.recorder
    }
}

/// Replay `trace` over `workload` under `cfg`.
///
/// Runs until every arrival has been ingested and every dispatched
/// invocation completed. Monitor ticks fire on the configured cadence
/// whenever work is pending or in flight.
pub fn replay(workload: Workload, trace: &Trace, cfg: PlaneConfig) -> ReplayResult {
    replay_traced(workload, trace, cfg, None)
}

/// [`replay`] with an optional telemetry attachment: the plane emits
/// the full lifecycle vocabulary into `tel`'s metrics registry and
/// trace ring as the replay runs. Telemetry is pure observation, so a
/// traced replay is event-for-event identical to a bare one; under
/// virtual time the emitted trace is itself deterministic
/// (property-tested in `rust/tests/telemetry.rs`).
pub fn replay_traced(
    workload: Workload,
    trace: &Trace,
    cfg: PlaneConfig,
    tel: Option<std::sync::Arc<crate::telemetry::Telemetry>>,
) -> ReplayResult {
    let monitor_period = cfg.monitor_period;
    let mut plane = ControlPlane::new(workload, cfg);
    if let Some(tel) = tel {
        plane.attach_telemetry(tel, 0);
    }
    let (makespan, events) = drive(&mut plane, trace, monitor_period);
    let mean_util = plane.mean_utilization(makespan.max(1));
    ReplayResult {
        plane,
        makespan,
        mean_util,
        events,
    }
}

/// Cluster replay outcome.
pub struct ClusterReplayResult {
    pub cluster: Cluster,
    /// All shards' records merged and completion-ordered, built once at
    /// the end of the replay (per-shard recorders stay available on
    /// `cluster.shards[i].recorder`).
    recorder: crate::metrics::Recorder,
    /// Virtual time when the last invocation completed (any shard).
    pub makespan: Nanos,
    /// Mean device utilization across every shard's devices.
    pub mean_util: f64,
    /// Events processed across the whole cluster.
    pub events: u64,
}

impl ClusterReplayResult {
    /// Cluster-level recorder (all shards merged, completion-ordered).
    pub fn recorder(&self) -> &crate::metrics::Recorder {
        &self.recorder
    }
}

/// Replay `trace` through an N-shard cluster: the router assigns each
/// arrival to a shard, and all shards advance on one global virtual
/// clock (see the module docs for the determinism contract). Monitor
/// ticks are cluster-global, so on a heterogeneous cluster they fire at
/// the *finest* per-shard cadence — every shard is sampled at least as
/// often as its own `monitor_period` asks.
pub fn replay_cluster(workload: Workload, trace: &Trace, cfg: ClusterConfig) -> ClusterReplayResult {
    replay_cluster_traced(workload, trace, cfg, None)
}

/// [`replay_cluster`] with an optional telemetry attachment: shard
/// planes emit the lifecycle, the cluster adds `route`/`epoch` events.
pub fn replay_cluster_traced(
    workload: Workload,
    trace: &Trace,
    cfg: ClusterConfig,
    tel: Option<std::sync::Arc<crate::telemetry::Telemetry>>,
) -> ClusterReplayResult {
    let monitor_period = (0..cfg.n_shards)
        .map(|s| cfg.plane_for(s).monitor_period)
        .min()
        .unwrap_or(cfg.plane.monitor_period);
    let mut cluster = Cluster::new(workload, cfg);
    if let Some(tel) = tel {
        cluster.attach_telemetry(tel);
    }
    let (makespan, events) = drive(&mut cluster, trace, monitor_period);
    let mean_util = cluster.mean_utilization(makespan.max(1));
    let recorder = cluster.merged_recorder();
    ClusterReplayResult {
        cluster,
        recorder,
        makespan,
        mean_util,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::RouterKind;
    use crate::scheduler::policies::PolicyKind;
    use crate::types::{secs, FuncId};
    use crate::workload::catalog::by_name;
    use crate::workload::trace::TraceEvent;

    fn tiny_workload() -> (Workload, Trace) {
        let mut w = Workload::default();
        let a = w.register(by_name("fft").unwrap(), 0, 1.0);
        let b = w.register(by_name("isoneural").unwrap(), 0, 1.0);
        let mut t = Trace::default();
        for i in 0..20 {
            t.events.push(TraceEvent {
                at: secs(i as f64 * 0.8),
                func: if i % 2 == 0 { a } else { b },
            });
        }
        t.sort();
        (w, t)
    }

    #[test]
    fn replay_completes_every_invocation() {
        let (w, t) = tiny_workload();
        let r = replay(w, &t, PlaneConfig::default());
        assert_eq!(r.recorder().len(), 20);
        assert!(r.makespan > 0);
        assert_eq!(r.plane.in_flight(), 0);
        assert_eq!(r.plane.pending(), 0);
    }

    #[test]
    fn replay_is_deterministic() {
        let (w, t) = tiny_workload();
        let r1 = replay(w.clone(), &t, PlaneConfig::default());
        let r2 = replay(w, &t, PlaneConfig::default());
        assert_eq!(r1.recorder().len(), r2.recorder().len());
        assert!(
            (r1.recorder().weighted_avg_latency_s()
                - r2.recorder().weighted_avg_latency_s())
            .abs()
                < 1e-12
        );
        assert_eq!(r1.makespan, r2.makespan);
    }

    #[test]
    fn latencies_are_causal() {
        let (w, t) = tiny_workload();
        let r = replay(w, &t, PlaneConfig::default());
        for rec in &r.recorder().records {
            assert!(rec.dispatched >= rec.arrived);
            assert!(rec.completed > rec.dispatched);
        }
    }

    #[test]
    fn fcfs_and_mqfq_both_run() {
        let (w, t) = tiny_workload();
        for kind in [PolicyKind::Fcfs, PolicyKind::Mqfq, PolicyKind::Batch] {
            let cfg = PlaneConfig {
                policy: kind,
                ..Default::default()
            };
            let r = replay(w.clone(), &t, cfg);
            assert_eq!(r.recorder().len(), 20, "{}", kind.name());
        }
    }

    #[test]
    fn warm_starts_dominate_after_first_wave() {
        let (w, t) = tiny_workload();
        let r = replay(w, &t, PlaneConfig::default());
        let stats = r.plane.pool_stats();
        assert!(stats.cold <= 4, "too many colds: {stats:?}");
        assert!(stats.gpu_warm + stats.host_warm >= 16);
    }

    #[test]
    fn utilization_positive_under_load() {
        let (w, t) = tiny_workload();
        let r = replay(w, &t, PlaneConfig::default());
        assert!(r.mean_util > 0.05, "{}", r.mean_util);
        assert!(r.mean_util <= 1.0);
    }

    #[test]
    fn higher_load_increases_latency() {
        let mut w = Workload::default();
        let f = w.register(by_name("lud").unwrap(), 0, 1.0);
        let mk = |iat: f64| {
            let mut t = Trace::default();
            for i in 0..30 {
                t.events.push(TraceEvent {
                    at: secs(i as f64 * iat),
                    func: f,
                });
            }
            t
        };
        let light = replay(w.clone(), &mk(5.0), PlaneConfig::default());
        let heavy = replay(w, &mk(0.5), PlaneConfig::default());
        assert!(
            heavy.recorder().weighted_avg_latency_s()
                > light.recorder().weighted_avg_latency_s()
        );
    }

    #[test]
    fn post_idle_ticks_fire_at_current_virtual_time() {
        // Bursty trace with a long idle gap: a burst at t≈0 drains in a
        // few seconds, then nothing until t=50s. The seed engine never
        // re-synced next_tick across the gap, so the first monitor tick
        // after the resume fired at a stale pre-gap virtual time; now
        // the cadence fast-forwards past the resume instant.
        let mut w = Workload::default();
        let f = w.register(by_name("fft").unwrap(), 0, 1.0);
        let mut t = Trace::default();
        for i in 0..3 {
            t.events.push(TraceEvent {
                at: secs(i as f64 * 0.3),
                func: f,
            });
        }
        t.events.push(TraceEvent {
            at: secs(50.0),
            func: f,
        });
        t.sort();
        let r = replay(w, &t, PlaneConfig::default());
        assert_eq!(r.recorder().len(), 4);
        let period = 200 * crate::types::MS;
        // End of the first busy window: last completion of the burst.
        let drain1 = r
            .recorder()
            .records
            .iter()
            .map(|rec| rec.completed)
            .filter(|&c| c < secs(50.0))
            .max()
            .unwrap();
        let samples = &r.plane.recorder.util_timeline;
        assert!(!samples.is_empty());
        let mut resumed = false;
        let mut prev = 0;
        for &(at, _) in samples {
            assert!(at > prev, "tick timestamps must be strictly increasing");
            prev = at;
            assert!(
                at <= drain1 + period || at > secs(50.0),
                "stale tick at {:.3}s inside the idle gap ({:.3}s..50s)",
                crate::types::to_secs(at),
                crate::types::to_secs(drain1)
            );
            resumed |= at > secs(50.0);
        }
        assert!(resumed, "post-resume window must be sampled");
        // Phase preserved: post-resume ticks stay on the 200 ms grid.
        let first_post = samples.iter().find(|(at, _)| *at > secs(50.0)).unwrap().0;
        assert_eq!(first_post % period, 0);
        assert!(first_post - secs(50.0) <= period);
    }

    #[test]
    fn funcid_out_of_range_is_rejected_by_debug_build() {
        // Guard: a trace referencing an unknown function would index out
        // of bounds — Trace::load validates this; replay assumes valid.
        let (w, mut t) = tiny_workload();
        t.events.truncate(1);
        t.events[0].func = FuncId(1); // valid
        let r = replay(w, &t, PlaneConfig::default());
        assert_eq!(r.recorder().len(), 1);
    }

    #[test]
    fn traced_replay_matches_bare_and_conserves_counts() {
        let (w, t) = tiny_workload();
        let (classes, _) = crate::telemetry::workload_classes(&w);
        let cfg = PlaneConfig::default();
        let tel = std::sync::Arc::new(crate::telemetry::Telemetry::new(
            &[cfg.n_devices()],
            &classes,
        ));
        let bare = replay(w.clone(), &t, cfg.clone());
        let traced = replay_traced(w, &t, cfg, Some(tel.clone()));
        // Telemetry is pure observation: identical replay.
        assert_eq!(bare.makespan, traced.makespan);
        assert_eq!(bare.events, traced.events);
        assert_eq!(bare.recorder().records, traced.recorder().records);
        // Conservation: every arrival counted in, every completion out.
        let m = tel.registry.shard(0);
        assert_eq!(m.submitted.get(), 20);
        assert_eq!(m.completed.get(), 20);
        assert_eq!(m.e2e_ns.count(), 20);
        let class_total: u64 = (0..2)
            .map(|c| tel.registry.class(c).unwrap().completed.get())
            .sum();
        assert_eq!(class_total, 20);
    }

    #[test]
    fn faulted_replay_resolves_every_invocation_exactly_once() {
        let (w, t) = tiny_workload();
        let cfg = PlaneConfig {
            faults: Some(crate::fault::FaultConfig {
                seed: 42,
                transient_rate: 0.3,
                straggler_rate: 0.1,
                retry_budget: 2,
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut r = replay(w, &t, cfg);
        let fates = r.plane.drain_fault_fates();
        assert_eq!(
            r.recorder().len() + fates.len(),
            20,
            "every submit resolves exactly once (success or terminal fate)"
        );
        assert_eq!(r.plane.in_flight(), 0);
        assert_eq!(r.plane.pending(), 0);
        let st = r.plane.fault_stats();
        assert!(
            st.faults_transient + st.faults_straggler > 0,
            "the storm must inject something at these rates: {st:?}"
        );
        assert_eq!(st.retry_exhausted, fates.len() as u64);
    }

    #[test]
    fn faulted_replay_is_deterministic() {
        let (w, t) = tiny_workload();
        let cfg = PlaneConfig {
            faults: Some(crate::fault::FaultConfig {
                seed: 7,
                transient_rate: 0.25,
                straggler_rate: 0.1,
                ..Default::default()
            }),
            ..Default::default()
        };
        let r1 = replay(w.clone(), &t, cfg.clone());
        let r2 = replay(w, &t, cfg);
        assert_eq!(r1.makespan, r2.makespan);
        assert_eq!(r1.events, r2.events);
        assert_eq!(r1.recorder().records, r2.recorder().records);
        assert_eq!(r1.plane.fault_stats(), r2.plane.fault_stats());
    }

    #[test]
    fn neutral_fault_plan_replay_is_bit_identical() {
        let (w, t) = tiny_workload();
        let bare = replay(w.clone(), &t, PlaneConfig::default());
        let neutral = replay(
            w,
            &t,
            PlaneConfig {
                faults: Some(crate::fault::FaultConfig::default()),
                ..Default::default()
            },
        );
        assert_eq!(bare.makespan, neutral.makespan);
        assert_eq!(bare.events, neutral.events);
        assert_eq!(bare.recorder().records, neutral.recorder().records);
    }

    #[test]
    fn device_failure_mid_replay_recovers() {
        let (w, t) = tiny_workload();
        let mut cfg = PlaneConfig::uniform(
            2,
            crate::gpu::V100,
            crate::gpu::MultiplexMode::Plain,
        );
        cfg.faults = Some(crate::fault::FaultConfig {
            device_failures: vec![(secs(2.0), crate::types::GpuId(0))],
            device_recoveries: vec![(secs(8.0), crate::types::GpuId(0))],
            ..Default::default()
        });
        let mut r = replay(w, &t, cfg);
        let fates = r.plane.drain_fault_fates();
        assert_eq!(r.recorder().len() + fates.len(), 20);
        assert!(r.plane.fault_stats().faults_device >= 1);
        assert_eq!(r.plane.live_devices(), 2, "scheduled recovery rejoined");
        assert_eq!(r.plane.in_flight(), 0);
        assert_eq!(r.plane.pending(), 0);
    }

    #[test]
    fn cluster_replay_completes_and_drains() {
        let (w, t) = tiny_workload();
        let r = replay_cluster(
            w,
            &t,
            ClusterConfig {
                n_shards: 3,
                router: RouterKind::RoundRobin,
                ..Default::default()
            },
        );
        assert_eq!(r.recorder().len(), 20);
        assert_eq!(r.cluster.pending(), 0);
        assert_eq!(r.cluster.in_flight(), 0);
        assert!(r.makespan > 0);
        assert_eq!(r.cluster.routed.iter().sum::<u64>(), 20);
    }

    #[test]
    fn cluster_replay_is_deterministic() {
        let (w, t) = tiny_workload();
        let cfg = ClusterConfig {
            n_shards: 4,
            router: RouterKind::StickyCh,
            ..Default::default()
        };
        let r1 = replay_cluster(w.clone(), &t, cfg.clone());
        let r2 = replay_cluster(w, &t, cfg);
        assert_eq!(r1.makespan, r2.makespan);
        assert_eq!(r1.events, r2.events);
        assert_eq!(r1.cluster.routed, r2.cluster.routed);
        assert_eq!(r1.recorder().records, r2.recorder().records);
    }

    #[test]
    fn more_shards_cut_latency_under_heavy_load() {
        // Weak sanity: the same overloaded trace on 4 shards must beat
        // 1 shard on average latency (more hardware, same work).
        let (w, t) = tiny_workload();
        let mut dense = t.clone();
        for e in &mut dense.events {
            e.at /= 8; // 8× the offered rate
        }
        dense.sort();
        let one = replay_cluster(w.clone(), &dense, ClusterConfig {
            n_shards: 1,
            router: RouterKind::LeastLoaded,
            ..Default::default()
        });
        let four = replay_cluster(w, &dense, ClusterConfig {
            n_shards: 4,
            router: RouterKind::LeastLoaded,
            ..Default::default()
        });
        assert!(
            four.recorder().weighted_avg_latency_s()
                <= one.recorder().weighted_avg_latency_s()
        );
    }
}
