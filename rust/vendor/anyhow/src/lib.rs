//! Minimal offline stand-in for the `anyhow` crate: just the subset the
//! repo uses — [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros,
//! and the [`Context`] extension trait. See `../README.md`.

use std::error::Error as StdError;
use std::fmt;

/// A message-carrying error with an optional source, mirroring
/// `anyhow::Error` closely enough for `{e}`/`{e:?}` formatting and `?`
/// conversions. Deliberately does NOT implement [`std::error::Error`],
/// exactly like the real crate, so the blanket `From` below is coherent.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().and_then(StdError::source);
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{context}: {e}"),
            source: Some(Box::new(e)),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
            source: Some(Box::new(e)),
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_layers_compose() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading x").unwrap_err();
        assert_eq!(e.to_string(), "reading x: gone");
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero is invalid");
            }
            Err(anyhow!("got {x}"))
        }
        assert_eq!(f(0).unwrap_err().to_string(), "zero is invalid");
        assert_eq!(f(7).unwrap_err().to_string(), "got 7");
    }
}
