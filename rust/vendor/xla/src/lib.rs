//! Offline stub of the `xla` (xla_extension / PJRT) bindings used by
//! `mqfq::runtime`. The build container has no registry or native
//! xla_extension, so this crate keeps the workspace compiling: client
//! construction succeeds (loading is lazy), and every call that would
//! touch a real artifact returns [`Error`] with a clear message. Swap in
//! the real bindings (same API subset) to execute HLO artifacts.

use std::fmt;

/// Error type; formatted with `{:?}` by callers, like the real crate's.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (offline `xla` stub — swap in the real \
         xla_extension bindings to execute artifacts)"
    ))
}

/// An HLO module parsed from text. The stub never parses.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// An addressable PJRT device.
pub struct PjRtDevice;

/// A device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// The PJRT client. Construction succeeds — runtimes create the client
/// eagerly but load artifacts lazily, so schedulers/sims/tests that
/// never execute an artifact run entirely green on the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn addressable_devices(&self) -> Vec<PjRtDevice> {
        vec![PjRtDevice]
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_artifact_paths_error() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu-stub");
        assert_eq!(c.addressable_devices().len(), 1);
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = c.compile(&XlaComputation::from_proto(&HloModuleProto)).unwrap_err();
        assert!(format!("{e:?}").contains("stub"));
    }
}
