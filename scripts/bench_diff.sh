#!/usr/bin/env bash
# Diff two BENCH_*.json artifacts (perf, cluster, ...) for cross-PR
# trajectory tracking: per-row numeric deltas plus regression flagging.
#
# Usage: scripts/bench_diff.sh OLD.json NEW.json [--threshold PCT] [--strict]
#
#   --threshold PCT   flag a metric as moved when |delta| > PCT (default 10)
#   --strict          exit 1 if any flagged move is a *regression*
#
# Direction is inferred from the metric name: latency/time/cold-ratio
# style metrics regress upward; speedup/throughput/fairness style
# metrics regress downward; unclassified metrics are reported but never
# flagged as regressions.
set -euo pipefail

if [[ $# -lt 2 ]]; then
    echo "usage: $0 OLD.json NEW.json [--threshold PCT] [--strict]" >&2
    exit 2
fi

OLD=$1
NEW=$2
shift 2
THRESHOLD=10
STRICT=0
while [[ $# -gt 0 ]]; do
    case "$1" in
        --threshold) THRESHOLD=$2; shift 2 ;;
        --strict) STRICT=1; shift ;;
        *) echo "unknown option $1" >&2; exit 2 ;;
    esac
done

python3 - "$OLD" "$NEW" "$THRESHOLD" "$STRICT" <<'PY'
import json
import sys

old_path, new_path, threshold, strict = (
    sys.argv[1],
    sys.argv[2],
    float(sys.argv[3]),
    sys.argv[4] == "1",
)

# Metrics where bigger is worse / better; anything else is neutral.
WORSE_UP = ("_ns", "latency", "p50", "p99", "wavg", "cold_ratio", "makespan",
            "imbalance", "blocking", "queue")
BETTER_UP = ("speedup", "events_per_sec", "fairness", "jain", "util",
             "throughput", "iters")


def direction(path):
    leaf = path.rsplit(".", 1)[-1].lower()
    if any(k in leaf for k in WORSE_UP):
        return "worse-up"
    if any(k in leaf for k in BETTER_UP):
        return "better-up"
    return "neutral"


def flatten(value, prefix, out):
    """path -> number, with bench rows keyed by their identity fields."""
    if isinstance(value, dict):
        # Key sweep/bench rows by what identifies them, not array index,
        # so adding a row to one file doesn't misalign the rest.
        for key, sub in value.items():
            flatten(sub, f"{prefix}.{key}" if prefix else key, out)
    elif isinstance(value, list):
        for i, sub in enumerate(value):
            label = str(i)
            if isinstance(sub, dict):
                ident = [str(sub[k]) for k in ("fleet", "router", "impl", "name",
                                               "grace", "batch", "estimator",
                                               "shape", "loop", "clients",
                                               "connections",
                                               "shards", "flows", "active",
                                               "telemetry",
                                               "phase", "window",
                                               "fault", "breaker", "shed") if k in sub]
                if ident:
                    label = ":".join(ident)
            flatten(sub, f"{prefix}[{label}]", out)
    elif isinstance(value, (int, float)) and not isinstance(value, bool):
        out[prefix] = float(value)


def load(path):
    out = {}
    with open(path) as f:
        flatten(json.load(f), "", out)
    return out


old, new = load(old_path), load(new_path)
shared = sorted(set(old) & set(new))
only_old = sorted(set(old) - set(new))
only_new = sorted(set(new) - set(old))

moved, regressions = [], []
for path in shared:
    a, b = old[path], new[path]
    if a == b:
        continue
    delta = (b - a) / abs(a) * 100.0 if a != 0 else float("inf")
    if abs(delta) <= threshold:
        continue
    d = direction(path)
    regressed = (d == "worse-up" and b > a) or (d == "better-up" and b < a)
    moved.append((path, a, b, delta, d, regressed))
    if regressed:
        regressions.append(path)

print(f"bench diff: {old_path} -> {new_path}")
print(f"  {len(shared)} shared metrics, {len(moved)} moved more than {threshold:g}%")
for path, a, b, delta, d, regressed in moved:
    flag = " REGRESSION" if regressed else ""
    sign = "+" if delta >= 0 else ""
    print(f"  {path}: {a:g} -> {b:g} ({sign}{delta:.1f}%){flag}")
for path in only_old:
    print(f"  removed: {path}")
for path in only_new:
    print(f"  added:   {path}")

if regressions:
    print(f"{len(regressions)} regression(s) flagged")
    if strict:
        sys.exit(1)
elif not moved:
    print("  no metric moved beyond the threshold")
PY
