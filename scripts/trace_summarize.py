#!/usr/bin/env python3
"""Fold a lifecycle trace (JSONL) into a per-phase latency breakdown.

Works on both trace sources, which share one event vocabulary:

  rust/target/release/mqfq replay ... --trace-out TRACE.jsonl
  rust/target/release/mqfq admin --host H --port P trace > TRACE.jsonl

Each line is one event:

  {"seq":N,"at":NS,"kind":"...","shard":S[,"inv":I][,"func":F],"a":A,"b":B,"c":C}

Lifecycle joins are keyed on (shard, inv). Field semantics per kind
(see rust/src/telemetry/trace.rs):

  submit                              accepted / arrived
  route       a=epoch b=spilled       router decision (serving path only)
  enqueue     a=flow_vt b=global_vt   entered its flow queue
  dispatch    a=start_kind b=boot_ns  device chosen (0=cold 1=host 2=gpu-warm)
  exec_start  a=mem_blocking_ns       kernel actually starts
  complete    a=e2e_ns b=exec_ns      finished
  grace       a=window_ns b=iat_ns    emptied flow held Active (anticipation)
  batch       a=size b=vt_ns          same-flow batch dispatched
  d_resize    a=new_d b=old_d         adaptive-D controller resized tokens
  estimate    a=pred_ns b=actual_ns   estimator accuracy at completion
  fault       a=kind b=attempt c=gpu  attempt failed (0=device 1=transient
                                      2=straggler)
  requeue     a=attempts              failed attempt back at flow head
  breaker_state a=state               breaker moved (0=closed 1=open
                                      2=half-open)
  shed        a=pred_wait_ns          admission shed by overload policy

Derived phases (nanoseconds in the trace, reported in ms):

  queue_wait = dispatch.at - submit.at
  boot       = dispatch.b            (container/model boot, 0 when warm)
  mem_block  = exec_start.a          (demand-fault blocking before exec)
  exec       = complete.at - exec_start.at
  e2e        = complete.a
  est_error  = |estimate.a - estimate.b|  (predicted vs actual exec)

Usage: trace_summarize.py [TRACE.jsonl ...] [--json]
Reads stdin when no file is given. --json emits a machine-readable doc
(bench_diff.sh-compatible) instead of the table.
"""

import json
import sys

START_KINDS = {0: "cold", 1: "host_warm", 2: "gpu_warm"}


def percentile(sorted_vals, q):
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[idx]


def phase_stats(vals):
    vals = sorted(vals)
    n = len(vals)
    return {
        "count": n,
        "mean_ms": (sum(vals) / n / 1e6) if n else 0.0,
        "p50_ms": percentile(vals, 0.50) / 1e6,
        "p99_ms": percentile(vals, 0.99) / 1e6,
        "max_ms": (vals[-1] / 1e6) if n else 0.0,
    }


def read_events(paths):
    streams = [open(p) for p in paths] if paths else [sys.stdin]
    skipped = 0
    for f in streams:
        for line in f:
            line = line.strip()
            if not line or not line.startswith("{"):
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                skipped += 1
                continue
            if "kind" not in ev or "at" not in ev:
                skipped += 1
                continue
            yield ev
        if f is not sys.stdin:
            f.close()
    if skipped:
        print(f"note: skipped {skipped} non-event line(s)", file=sys.stderr)


def summarize(events):
    kind_counts = {}
    start_kinds = {}
    spills = 0
    epochs = []
    grace_holds = 0
    batch_dispatches = 0
    batched_invocations = 0
    d_resizes = 0
    FAULT_KINDS = {0: "device", 1: "transient", 2: "straggler"}
    BREAKER_STATES = {0: "closed", 1: "open", 2: "half_open"}
    faults = {}
    requeues = 0
    breaker_transitions = {}
    sheds = 0
    # (shard, inv) -> {phase timestamps / fields}
    invs = {}
    phases = {"queue_wait": [], "boot": [], "mem_block": [], "exec": [],
              "e2e": [], "est_error": []}

    for ev in events:
        kind = ev["kind"]
        kind_counts[kind] = kind_counts.get(kind, 0) + 1
        if "inv" in ev:
            key = (ev.get("shard", 0), ev["inv"])
        else:
            key = None

        if kind == "route" and ev.get("b"):
            spills += 1
        elif kind == "epoch":
            epochs.append((ev.get("shard", 0), ev.get("a", 0)))
        elif kind == "submit" and key:
            invs.setdefault(key, {})["submit_at"] = ev["at"]
        elif kind == "dispatch" and key:
            rec = invs.setdefault(key, {})
            rec["dispatch_at"] = ev["at"]
            sk = START_KINDS.get(ev.get("a", -1), "unknown")
            start_kinds[sk] = start_kinds.get(sk, 0) + 1
            boot = ev.get("b", 0)
            if boot:
                phases["boot"].append(boot)
        elif kind == "exec_start" and key:
            rec = invs.setdefault(key, {})
            rec["exec_start_at"] = ev["at"]
            block = ev.get("a", 0)
            if block:
                phases["mem_block"].append(block)
        elif kind == "complete" and key:
            rec = invs.setdefault(key, {})
            rec["complete_at"] = ev["at"]
            phases["e2e"].append(ev.get("a", 0))
            if "exec_start_at" in rec:
                phases["exec"].append(ev["at"] - rec["exec_start_at"])
        elif kind == "grace":
            grace_holds += 1
        elif kind == "batch":
            batch_dispatches += 1
            batched_invocations += ev.get("a", 0)
        elif kind == "d_resize":
            d_resizes += 1
        elif kind == "estimate":
            phases["est_error"].append(abs(ev.get("a", 0) - ev.get("b", 0)))
        elif kind == "fault":
            fk = FAULT_KINDS.get(ev.get("a", -1), "unknown")
            faults[fk] = faults.get(fk, 0) + 1
        elif kind == "requeue":
            requeues += 1
        elif kind == "breaker_state":
            bs = BREAKER_STATES.get(ev.get("a", -1), "unknown")
            breaker_transitions[bs] = breaker_transitions.get(bs, 0) + 1
        elif kind == "shed":
            sheds += 1

    for rec in invs.values():
        if "submit_at" in rec and "dispatch_at" in rec:
            phases["queue_wait"].append(rec["dispatch_at"] - rec["submit_at"])

    completed = kind_counts.get("complete", 0)
    cold = start_kinds.get("cold", 0)
    dispatched = sum(start_kinds.values())
    return {
        "events": sum(kind_counts.values()),
        "kinds": dict(sorted(kind_counts.items())),
        "invocations_completed": completed,
        "start_kinds": dict(sorted(start_kinds.items())),
        "cold_ratio": (cold / dispatched) if dispatched else 0.0,
        "router_spills": spills,
        "epoch_changes": len(epochs),
        "grace_holds": grace_holds,
        "batch_dispatches": batch_dispatches,
        "batched_invocations": batched_invocations,
        "d_resizes": d_resizes,
        "faults": dict(sorted(faults.items())),
        "requeues": requeues,
        "breaker_transitions": dict(sorted(breaker_transitions.items())),
        "sheds": sheds,
        "phases": {name: phase_stats(vals) for name, vals in phases.items()},
    }


def main():
    argv = sys.argv[1:]
    as_json = "--json" in argv
    paths = [a for a in argv if a != "--json"]
    summary = summarize(read_events(paths))
    if summary["events"] == 0:
        print("trace_summarize: no events found", file=sys.stderr)
        sys.exit(1)

    if as_json:
        json.dump(summary, sys.stdout, indent=2)
        print()
        return

    src = ", ".join(paths) if paths else "<stdin>"
    print(f"trace summary: {src}")
    print(f"  events: {summary['events']}  "
          f"completed: {summary['invocations_completed']}  "
          f"cold ratio: {summary['cold_ratio']:.3f}  "
          f"spills: {summary['router_spills']}  "
          f"epoch changes: {summary['epoch_changes']}")
    if (summary["grace_holds"] or summary["batch_dispatches"]
            or summary["d_resizes"]):
        print(f"  anticipation: grace holds={summary['grace_holds']}  "
              f"batches={summary['batch_dispatches']} "
              f"(covering {summary['batched_invocations']} invocations)  "
              f"D resizes={summary['d_resizes']}")
    if (summary["faults"] or summary["requeues"]
            or summary["breaker_transitions"] or summary["sheds"]):
        fault_str = " ".join(f"{k}={n}" for k, n in summary["faults"].items())
        brk_str = " ".join(
            f"{k}={n}" for k, n in summary["breaker_transitions"].items())
        print(f"  faults: {fault_str or 'none'}  "
              f"requeues={summary['requeues']}  "
              f"breaker: {brk_str or 'none'}  "
              f"sheds={summary['sheds']}")
    print("  event kinds: "
          + "  ".join(f"{k}={n}" for k, n in summary["kinds"].items()))
    if summary["start_kinds"]:
        print("  start kinds: "
              + "  ".join(f"{k}={n}" for k, n in summary["start_kinds"].items()))
    print(f"  {'phase':<12}{'count':>8}{'mean ms':>12}{'p50 ms':>12}"
          f"{'p99 ms':>12}{'max ms':>12}")
    for name, st in summary["phases"].items():
        print(f"  {name:<12}{st['count']:>8}{st['mean_ms']:>12.3f}"
              f"{st['p50_ms']:>12.3f}{st['p99_ms']:>12.3f}{st['max_ms']:>12.3f}")


if __name__ == "__main__":
    main()
