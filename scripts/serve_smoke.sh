#!/usr/bin/env bash
# End-to-end serving smoke test: start `serve` in model mode (no PJRT
# artifacts needed), drive sync + async + pipelined-tagged + push
# invocations through an independent python3 client speaking protocol
# v1 (plus one legacy line), and assert the server's stats. Wired into
# `make check` and CI.
# Usage: scripts/serve_smoke.sh  (or `make smoke`)
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=target/release/mqfq-sticky
# Always (re)build: a no-op when fresh, and guarantees the smoke never
# exercises a stale binary when run standalone via `make smoke`.
echo "== cargo build --release (serve smoke) =="
cargo build --release

PORT="${SERVE_SMOKE_PORT:-18077}"
FPORT=$((PORT + 1))
LOG="$(mktemp)"
FLOG="$(mktemp)"
"$BIN" serve --addr "127.0.0.1:$PORT" --scale 0.001 --shards 4 --router sticky \
  >"$LOG" 2>&1 &
SRV=$!
# Second server with a seeded fault plan: transient exec faults retried
# transparently, plus a poison-function circuit breaker (threshold 1.0
# so only the all-fail poison tenant can trip it; 1 s cooldown so the
# half-open probe path runs inside the smoke).
"$BIN" serve --addr "127.0.0.1:$FPORT" --scale 0.001 --shards 1 \
  --fault-seed 7 --fault-rate 0.15 --retry-budget 5 \
  --poison 4:1.0 --breaker 8:1.0:1 \
  >"$FLOG" 2>&1 &
FSRV=$!
trap 'kill "$SRV" "$FSRV" 2>/dev/null || true; rm -f "$LOG" "$FLOG"' EXIT

python3 - "$PORT" <<'EOF'
import json, socket, sys, time

port = int(sys.argv[1])

# Wait for the listener (the server prints its banner after binding).
deadline = time.time() + 30
while True:
    try:
        s = socket.create_connection(("127.0.0.1", port), timeout=2)
        break
    except OSError:
        if time.time() > deadline:
            sys.exit("serve never came up on port %d" % port)
        time.sleep(0.1)

s.settimeout(60)
f = s.makefile("rwb")

def call(req):
    f.write((json.dumps(req) + "\n").encode())
    f.flush()
    line = f.readline()
    assert line, "server closed the connection"
    return json.loads(line)

def legacy(line):
    f.write((line + "\n").encode())
    f.flush()
    return f.readline().decode().strip()

# hello handshake + version negotiation.
hello = call({"cmd": "hello", "v": 1})
assert hello["ok"] and hello["proto"] == 1, hello
assert hello["server"] == "rt-cluster", hello
bad = call({"cmd": "hello", "v": 99})
assert not bad["ok"] and bad["error"] == "unsupported-version", bad

# describe: cluster shape + functions.
desc = call({"cmd": "describe"})
assert desc["shards"] == 4 and desc["router"] == "sticky-ch", desc
assert "isoneural-0" in desc["functions"], desc

# sync invoke.
done = call({"cmd": "invoke", "func": "isoneural-0", "mode": "sync",
             "deadline_ms": 60000})
assert done["ok"] and done["type"] == "done", done
assert done["start"] == "cold" and done["latency_ms"] > 0, done

# async invoke: ticket -> wait.
acc = call({"cmd": "invoke", "func": "fft-0", "mode": "async"})
assert acc["ok"] and acc["type"] == "ticket", acc
out = call({"cmd": "wait", "ticket": acc["ticket"], "deadline_ms": 60000})
assert out["ok"] and out["type"] == "done" and out["func"] == "fft-0", out

# error taxonomy.
err = call({"cmd": "invoke", "func": "ghost"})
assert not err["ok"] and err["error"] == "unknown-function", err

# stats: both invocations served, nothing stuck.
stats = call({"cmd": "stats"})
assert stats["invocations"] == 2, stats
assert stats["pending"] == 0 and stats["in_flight"] == 0, stats

# legacy alias on the same connection.
line = legacy("stats")
assert line.startswith("ok invocations=2"), line

# Minimum smoke throughput: 100 sequential sync invokes must complete
# under a generous wall bound (catches a serving path that limps —
# e.g. a wedged worker pool or timer — without being a benchmark).
N, BOUND_S = 100, 30.0
t0 = time.time()
for i in range(N):
    done = call({"cmd": "invoke", "func": "isoneural-0", "mode": "sync",
                 "deadline_ms": 60000})
    assert done["ok"] and done["type"] == "done", done
wall = time.time() - t0
assert wall < BOUND_S, "throughput smoke: %d invokes took %.1fs (bound %.0fs)" % (
    N, wall, BOUND_S)
stats = call({"cmd": "stats"})
assert stats["invocations"] == 2 + N, stats
assert stats["pending"] == 0 and stats["in_flight"] == 0, stats

# Telemetry: per-shard stats breakdown conserves against the aggregate.
assert len(stats["shards"]) == 4, stats
assert sum(r["completed"] for r in stats["shards"]) == stats["invocations"], stats
assert all(r["pending"] == 0 and r["in_flight"] == 0 for r in stats["shards"]), stats
assert all(r["state"] == "up" and r["epoch"] == 0 for r in stats["shards"]), stats

# metrics round-trip, Prometheus text: typed families present.
m = call({"cmd": "metrics", "format": "prom"})
assert m["ok"] and m["type"] == "metrics" and m["format"] == "prom", m
assert "# TYPE mqfq_completed_total counter" in m["body"], m["body"][:400]
assert "mqfq_e2e_ns" in m["body"] and "mqfq_trace_dropped_events_total" in m["body"], m["body"][:400]

# metrics round-trip, JSON: versioned schema, and the registry's own
# completion counters conserve against the stats aggregate.
m = call({"cmd": "metrics", "format": "json"})
assert m["ok"] and m["format"] == "json", m
doc = json.loads(m["body"])
assert doc["schema"] == "mqfq-metrics/v1", doc
assert sum(r["completed"] for r in doc["shards"]) == stats["invocations"], doc["shards"]
assert sum(r["errors"] for r in doc["shards"]) == 0, doc["shards"]
err = call({"cmd": "metrics", "format": "yaml"})
assert not err["ok"] and err["error"] == "bad-request", err

# trace: the live server speaks the simulator's lifecycle vocabulary
# (plus the serving-only route event), one complete per invocation.
t = call({"cmd": "trace"})
assert t["ok"] and t["type"] == "trace" and t["count"] == len(t["events"]), t["count"]
kinds = {e["kind"] for e in t["events"]}
for k in ("route", "submit", "enqueue", "dispatch", "exec_start", "complete"):
    assert k in kinds, (k, sorted(kinds))
completes = sum(1 for e in t["events"] if e["kind"] == "complete")
assert completes == stats["invocations"], (completes, stats["invocations"])

# Elastic membership round-trip: drain -> rejoin -> kill -> rejoin,
# with routing and ticket-fate conservation asserted at each step.
m = call({"cmd": "membership"})
assert m["ok"] and len(m["shards"]) == 4, m
assert all(s["state"] == "up" for s in m["shards"]), m
assert m["accepted"] == m["completed"] + m["failed"], m
served = m["completed"]

m = call({"cmd": "drain", "shard": 1})
assert m["ok"] and m["shards"][1]["state"] == "draining", m
# A draining shard takes no new work; invokes land elsewhere.
done = call({"cmd": "invoke", "func": "isoneural-0", "mode": "sync",
             "deadline_ms": 60000})
assert done["ok"] and done["shard"] != 1, done
m = call({"cmd": "join", "shard": 1})
assert m["ok"] and m["shards"][1]["state"] == "up", m

# Abrupt kill of an idle shard: nothing stranded, epoch bumped, ring
# healed; the shard then rejoins cold and the cluster still conserves.
m = call({"cmd": "kill", "shard": 2})
assert m["ok"] and m["shards"][2]["state"] == "dead", m
assert m["shards"][2]["epoch"] == 1, m
done = call({"cmd": "invoke", "func": "isoneural-0", "mode": "sync",
             "deadline_ms": 60000})
assert done["ok"] and done["shard"] != 2, done
m = call({"cmd": "join", "shard": 2})
assert m["ok"] and m["shards"][2]["state"] == "up", m

# Verb taxonomy: membership verbs on an out-of-range shard reject.
err = call({"cmd": "drain", "shard": 9})
assert not err["ok"] and err["error"] == "bad-request", err

m = call({"cmd": "membership"})
assert m["completed"] == served + 2 and m["failed"] == 0, m
assert m["accepted"] == m["completed"], m

# Pipelined tagged requests: two lines in one flush, replies carry the
# request id back so the client reassembles them.
f.write((json.dumps({"id": 11, "cmd": "invoke", "func": "fft-0",
                     "mode": "async"}) + "\n"
         + json.dumps({"id": 12, "cmd": "stats"}) + "\n").encode())
f.flush()
byid = {r["id"]: r for r in (json.loads(f.readline()), json.loads(f.readline()))}
assert byid[11]["type"] == "ticket" and byid[12]["type"] == "stats", byid
out = call({"cmd": "wait", "ticket": byid[11]["ticket"], "deadline_ms": 60000})
assert out["ok"] and out["type"] == "done", out

# Out-of-order replies: a blocking wait on a cold function pipelined
# ahead of stats — the immediate stats answer overtakes the deferred
# wait completion.
acc = call({"cmd": "invoke", "func": "lud-0", "mode": "async"})
assert acc["ok"] and acc["type"] == "ticket", acc
f.write((json.dumps({"id": 21, "cmd": "wait", "ticket": acc["ticket"],
                     "deadline_ms": 60000}) + "\n"
         + json.dumps({"id": 22, "cmd": "stats"}) + "\n").encode())
f.flush()
first, second = json.loads(f.readline()), json.loads(f.readline())
assert first["id"] == 22 and first["type"] == "stats", (first, second)
assert second["id"] == 21 and second["type"] == "done", (first, second)

# Push completions: subscribe at submit, the completion arrives as an
# unsolicited push line tagged by ticket — no polling round trip.
acc = call({"cmd": "invoke", "func": "isoneural-0", "mode": "async",
            "push": True})
assert acc["ok"] and acc["type"] == "ticket", acc
push = json.loads(f.readline())
assert push["ok"] and push["type"] == "push", push
assert push["ticket"] == acc["ticket"] and push["func"] == "isoneural-0", push

# The serving metric family saw this connection and its pushes.
m = call({"cmd": "metrics", "format": "json"})
doc = json.loads(m["body"])
assert doc["serving"]["push_notifications"] >= 1, doc["serving"]
assert doc["serving"]["open_connections"] >= 1, doc["serving"]

call({"cmd": "quit"})
print("serve smoke: OK (sync + async + pipeline + push + errors + legacy "
      "+ telemetry + membership + %d invokes in %.2fs)" % (N, wall))
EOF

# -- Fault-tolerance round-trip against the fault-configured server:
# transient faults retried to completion, the poison tenant tripping
# the breaker into quarantine, and the half-open probe after cooldown.
python3 - "$FPORT" <<'EOF'
import json, socket, sys, time

port = int(sys.argv[1])
deadline = time.time() + 30
while True:
    try:
        s = socket.create_connection(("127.0.0.1", port), timeout=2)
        break
    except OSError:
        if time.time() > deadline:
            sys.exit("fault serve never came up on port %d" % port)
        time.sleep(0.1)

s.settimeout(60)
f = s.makefile("rwb")

def call(req):
    f.write((json.dumps(req) + "\n").encode())
    f.flush()
    line = f.readline()
    assert line, "fault server closed the connection"
    return json.loads(line)

def prom_sum(body, family):
    return sum(float(l.rsplit(" ", 1)[1]) for l in body.splitlines()
               if l.startswith(family))

hello = call({"cmd": "hello", "v": 1})
assert hello["ok"], hello

# Transient faults (rate 0.15/attempt) are retried server-side: every
# healthy sync invoke still completes — the client never sees a fault.
for _ in range(40):
    done = call({"cmd": "invoke", "func": "fft-0", "mode": "sync",
                 "deadline_ms": 60000})
    assert done["ok"] and done["type"] == "done", done
m = call({"cmd": "metrics", "format": "prom"})
assert prom_sum(m["body"], "mqfq_faults_transient_total") >= 1, m["body"][:400]
assert prom_sum(m["body"], "mqfq_retries_total") >= 1, m["body"][:400]
assert prom_sum(m["body"], "mqfq_retry_exhausted_total") == 0, m["body"][:400]

# The poison tenant (isoneural-0, fault rate 1.0) burns its retry
# budget (exec-failed), feeds the breaker all-fail samples, and trips
# it: subsequent invokes are quarantined without consuming attempts.
codes = []
for _ in range(12):
    r = call({"cmd": "invoke", "func": "isoneural-0", "mode": "sync",
              "deadline_ms": 60000})
    assert not r["ok"], r
    codes.append(r["error"])
assert "exec-failed" in codes, codes
assert codes[-1] == "quarantined", codes
m = call({"cmd": "metrics", "format": "prom"})
assert prom_sum(m["body"], "mqfq_breaker_trips_total") >= 1, m["body"][:400]
assert prom_sum(m["body"], "mqfq_retry_exhausted_total") >= 1, m["body"][:400]

# After the 1 s cooldown the breaker goes half-open: the next invoke is
# admitted as a probe (it still fails — the tenant is still poison — so
# the breaker re-opens and the following invoke is quarantined again).
time.sleep(1.2)
r = call({"cmd": "invoke", "func": "isoneural-0", "mode": "sync",
          "deadline_ms": 60000})
assert not r["ok"] and r["error"] == "exec-failed", r
m = call({"cmd": "metrics", "format": "prom"})
assert prom_sum(m["body"], "mqfq_breaker_probes_total") >= 1, m["body"][:400]
r = call({"cmd": "invoke", "func": "isoneural-0", "mode": "sync",
          "deadline_ms": 60000})
assert not r["ok"] and r["error"] == "quarantined", r

# Healthy traffic was never quarantined and nothing is stuck.
done = call({"cmd": "invoke", "func": "fft-0", "mode": "sync",
             "deadline_ms": 60000})
assert done["ok"], done
stats = call({"cmd": "stats"})
assert stats["pending"] == 0 and stats["in_flight"] == 0, stats

call({"cmd": "quit"})
print("serve smoke (faults): OK (transient retries + breaker trip "
      "+ quarantine + half-open probe)")
EOF
