#!/usr/bin/env bash
# Tier-1 gate + lints, from anywhere: build, test, clippy-clean.
# Usage: scripts/check.sh  (or `make check`)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== trace summarizer smoke (replay --trace-out | trace_summarize.py) =="
BIN=target/release/mqfq-sticky
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT
"$BIN" trace gen --kind zipf --funcs 4 --rate 1.0 --duration 30 --seed 7 \
  --out "$TMP/smoke.trace"
"$BIN" replay --trace "$TMP/smoke.trace" --policy mqfq \
  --trace-out "$TMP/smoke.jsonl" >/dev/null
python3 scripts/trace_summarize.py "$TMP/smoke.jsonl"
python3 scripts/trace_summarize.py "$TMP/smoke.jsonl" --json \
  | python3 -c '
import json, sys
d = json.load(sys.stdin)
assert d["invocations_completed"] > 0, d
for k in ("submit", "enqueue", "dispatch", "exec_start", "complete"):
    assert k in d["kinds"], (k, d["kinds"])
assert d["phases"]["e2e"]["count"] == d["invocations_completed"], d["phases"]
print("trace summarizer smoke: OK (%d events, %d completed)"
      % (d["events"], d["invocations_completed"]))
'

echo "check: OK"
