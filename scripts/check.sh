#!/usr/bin/env bash
# Tier-1 gate + lints, from anywhere: build, test, clippy-clean.
# Usage: scripts/check.sh  (or `make check`)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "check: OK"
